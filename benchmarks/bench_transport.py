"""PR 5 — HTTP frontend + client transport: threaded vs event loop.

Ask/tell traffic is many tiny request/response exchanges, so once
sampling (PR 2) and storage (PR 4) are O(1) per op the frontend is the
last layer whose per-request cost scales with *concurrency* instead of
with work.  Three tables, emitted together as ``BENCH_transport.json``:

* ``keepalive-contended`` — N concurrent keep-alive clients (1/8/32,
  plus 128 in the full run) hammering ask/tell pairs over shared
  studies, against both frontends.  Acceptance: the event loop is
  >= 2x pair throughput at 32+ clients, with p99 latency flat as the
  connection count grows (thread-per-connection degrades with N).
* ``pipelined-batch`` — K requests written back-to-back on one socket
  (HTTP pipelining): the event loop parses them out of one read.
* ``pooled-client`` — 8 threads sharing ONE transport: a single locked
  keep-alive socket vs ``PooledHttpTransport``'s checkout/checkin.

Columns: scenario, backend, clients, requests, wall_s, req_per_s,
pairs_per_s, p50_ms, p99_ms.
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import threading
import time

from repro.core.auth import TokenManager
from repro.core.client import Client, Study, suggestions
from repro.core.server import HopaasServer
from repro.core.storage import InMemoryStorage
from repro.core.transport import (HttpServiceRunner, HttpTransport,
                                  PooledHttpTransport)

_SPACE = {"x": suggestions.uniform(0.0, 1.0)}


def _row(scenario: str, backend: str, clients: int, requests: int,
         wall: float, pairs: int, lats_ms: list[float] | None = None) -> dict:
    row = {"scenario": scenario, "backend": backend, "clients": clients,
           "requests": requests, "wall_s": round(wall, 3),
           "req_per_s": round(requests / wall, 1),
           "pairs_per_s": round(pairs / wall, 1) if pairs else None}
    if lats_ms:
        lats = sorted(lats_ms)
        row["p50_ms"] = round(lats[len(lats) // 2], 2)
        row["p99_ms"] = round(lats[min(len(lats) - 1,
                                       int(len(lats) * 0.99))], 2)
    return row


def _runner(backend: str, tokens: TokenManager,
            n_workers: int = 2) -> HttpServiceRunner:
    storage = InMemoryStorage()
    workers = [HopaasServer(storage=storage, tokens=tokens, seed=i)
               for i in range(n_workers)]
    return HttpServiceRunner(workers, backend=backend).start()


def _study(client: Client, idx: int) -> Study:
    return Study(name=f"bench-transport-{idx}", properties=dict(_SPACE),
                 sampler={"name": "random"}, client=client)


_LOADGEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "_transport_loadgen.py")


def _contended(runner: HttpServiceRunner, token: str, *, n_clients: int,
               pairs_per_client: int,
               study_keys: list[str]) -> tuple[float, list[float]]:
    """N concurrent keep-alive clients x ask/tell pairs over shared
    studies -> (wall_s, per-pair latencies in ms).

    The load comes from *separate processes* (``_transport_loadgen``,
    stdlib-only raw sockets with pre-encoded requests): real campaign
    workers are remote, and an in-process load generator convoys with
    the server on the GIL badly enough to hide a 3x frontend difference
    behind scheduler noise.  2 generator processes are plenty — each
    drives up to half the clients with threads of its own.
    """
    n_procs = 2 if n_clients > 1 else 1
    split = [n_clients // n_procs + (1 if i < n_clients % n_procs else 0)
             for i in range(n_procs)]
    offsets = [sum(split[:i]) for i in range(n_procs)]
    procs = []
    for count, offset in zip(split, offsets):
        procs.append(subprocess.Popen(
            [sys.executable, _LOADGEN, "--host", str(runner.host),
             "--port", str(runner.port), "--token", token,
             "--keys", ",".join(study_keys), "--clients", str(count),
             "--pairs", str(pairs_per_client), "--offset", str(offset)],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True))
    try:
        for p in procs:                      # connection-setup barrier
            line = p.stdout.readline().strip()
            if line != "READY":
                raise RuntimeError(f"load generator failed to start: {line!r}")
        t0 = time.time()
        for p in procs:
            p.stdin.write("GO\n")
            p.stdin.flush()
        results = []
        for p in procs:
            out = json.loads(p.stdout.readline())
            if "errors" in out:
                raise RuntimeError(f"load generator errors: {out['errors']}")
            results.append(out)
        wall = time.time() - t0
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
            p.wait()
    return wall, [x for r in results for x in r["lat_ms"]]


def _shared_transport_load(runner: HttpServiceRunner, token: str, *,
                           n_threads: int, pairs_per_thread: int,
                           transport) -> tuple[float, list[float]]:
    """N threads sharing ONE client transport (the pooled-client
    scenario) — here the client layer is the subject, so both sides use
    the same full ``Client`` stack."""
    barrier = threading.Barrier(n_threads + 1)
    lat_ms: list[list[float]] = [[] for _ in range(n_threads)]
    shared = Client(transport, token, worker_id="pool")
    studies = [_study(shared, i) for i in range(4)]
    for s in studies:
        s._ensure_key()

    def worker(widx: int) -> None:
        study = studies[widx % len(studies)]
        barrier.wait()
        for _ in range(pairs_per_thread):
            t0 = time.perf_counter()
            trial = study.ask()
            study.tell(trial, value=(trial.x - 0.3) ** 2)
            lat_ms[widx].append((time.perf_counter() - t0) * 1e3)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.time()
    for t in threads:
        t.join()
    return time.time() - t0, [x for per in lat_ms for x in per]


def _pipelined(runner: HttpServiceRunner, n_requests: int) -> float:
    """K version GETs written in one send on one socket; wall until the
    K-th complete response arrives."""
    request = b"GET /api/version HTTP/1.1\r\nHost: bench\r\n\r\n"
    expected_each = None
    sk = socket.create_connection((runner.host, runner.port), timeout=30)
    try:
        # one warmup request to measure the exact response size
        sk.sendall(request)
        probe = b""
        while b"\r\n\r\n" not in probe:
            probe += sk.recv(65536)
        head = probe.split(b"\r\n\r\n", 1)[0].decode("latin-1").lower()
        length = next(int(l.split(":", 1)[1]) for l in head.split("\r\n")
                      if l.startswith("content-length:"))
        expected_each = probe.find(b"\r\n\r\n") + 4 + length
        while len(probe) < expected_each:
            probe += sk.recv(65536)
        t0 = time.time()
        sk.sendall(request * n_requests)
        got = 0
        while got < expected_each * n_requests:
            chunk = sk.recv(1 << 20)
            if not chunk:
                raise AssertionError("connection closed mid-pipeline")
            got += len(chunk)
        return time.time() - t0
    finally:
        sk.close()


def run(smoke: bool = False) -> list[dict]:
    client_counts = (1, 8, 32) if smoke else (1, 8, 32, 128)
    total_pairs = 768          # long enough to ride out scheduler noise
    pipeline_n = 200 if smoke else 2000
    reps = 3                   # median-of-3: shared CI boxes are noisy
    rows: list[dict] = []
    tokens = TokenManager()
    tok = tokens.issue("bench")

    # -- contended keep-alive ask/tell, both frontends -------------------
    contended: dict[tuple[str, int], dict] = {}
    for backend in ("threaded", "evloop"):
        for n_clients in client_counts:
            pairs_per_client = max(2, total_pairs // n_clients)
            pairs = pairs_per_client * n_clients
            attempts = []
            for _rep in range(reps):
                runner = _runner(backend, tokens)
                try:
                    # pre-create the shared studies (setup, not measured)
                    setup = Client(HttpTransport(runner.host, runner.port),
                                   tok)
                    keys = [_study(setup, i)._ensure_key()
                            for i in range(min(8, n_clients))]
                    wall, lats = _contended(
                        runner, tok, n_clients=n_clients,
                        pairs_per_client=pairs_per_client, study_keys=keys)
                finally:
                    runner.stop()
                attempts.append(_row("keepalive-contended", backend,
                                     n_clients, 2 * pairs, wall, pairs,
                                     lats))
            attempts.sort(key=lambda r: r["pairs_per_s"])
            row = dict(attempts[len(attempts) // 2], reps=reps)
            contended[(backend, n_clients)] = row
            rows.append(row)

    # -- acceptance summary: event loop vs threaded at >= 32 clients -----
    for n_clients in client_counts:
        if n_clients < 32:
            continue
        ev = contended[("evloop", n_clients)]
        th = contended[("threaded", n_clients)]
        rows.append({"scenario": f"speedup-{n_clients}c",
                     "backend": "evloop/threaded", "clients": n_clients,
                     "requests": None, "wall_s": None,
                     "req_per_s": None,
                     "pairs_per_s": round(
                         ev["pairs_per_s"] / th["pairs_per_s"], 2),
                     "p50_ms": None, "p99_ms": None})

    # -- pipelined batch: one socket, K requests in one write ------------
    for backend in ("threaded", "evloop"):
        runner = _runner(backend, tokens)
        try:
            wall = _pipelined(runner, pipeline_n)
        finally:
            runner.stop()
        rows.append(_row("pipelined-batch", backend, 1, pipeline_n, wall, 0))

    # -- one shared transport, 8 threads: locked socket vs pool ----------
    n_threads = 8
    pairs_per_thread = max(2, total_pairs // n_threads)
    for label, make_transport in (
            ("http-shared-1conn",
             lambda r: HttpTransport(r.host, r.port)),
            ("http-pooled",
             lambda r: PooledHttpTransport(r.host, r.port,
                                           pool_size=n_threads))):
        runner = _runner("evloop", tokens)
        try:
            wall, lats = _shared_transport_load(
                runner, tok, n_threads=n_threads,
                pairs_per_thread=pairs_per_thread,
                transport=make_transport(runner))
        finally:
            runner.stop()
        pairs = pairs_per_thread * n_threads
        rows.append(_row(f"pooled-client/{label}", "evloop", n_threads,
                         2 * pairs, wall, pairs, lats))

    out_dir = "experiments/benchmarks"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_transport.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows
