"""Paper sec. 4 — the MARCONI-100 campaign shape: many concurrent,
heterogeneous, *unreliable* workers driving one study over the real HTTP
wire.  Reports elasticity (stagger), fault tolerance (failure injection +
lease requeue), and scaling of trials/s with workers.

Columns: workers, failure_rate, batch, trials, completed, failed, pruned,
best_loss, wall_s.  ``batch > 1`` rows drive the batched ask/tell wire
protocol (one round trip per k trials).
"""
from __future__ import annotations

from repro.core.auth import TokenManager
from repro.core.campaign import run_campaign
from repro.core.client import suggestions
from repro.core.server import HopaasServer
from repro.core.storage import InMemoryStorage
from repro.core.transport import HttpServiceRunner, HttpTransport


def _objective(params, report):
    # cheap analytic objective: noisy quadratic with prune reports
    import random
    val = (params["x"] - 0.7) ** 2 + (params["y"] + 0.2) ** 2
    for step in range(5):
        if report(step, val + (5 - step) * 0.1):
            return val
    return val + random.Random(int(params["x"] * 1e6)).gauss(0, 1e-3)


def run(n_trials: int = 60, smoke: bool = False) -> list[dict]:
    rows = []
    if smoke:
        n_trials = 24
        cases = ((4, 0.0, 1), (8, 0.15, 1), (8, 0.0, 4))
    else:
        cases = ((4, 0.0, 1), (16, 0.0, 1), (16, 0.15, 1), (24, 0.25, 1),
                 (16, 0.0, 4))
    for n_workers, failure_rate, batch_size in cases:
        storage = InMemoryStorage()
        tokens = TokenManager()
        backends = [HopaasServer(storage=storage, tokens=tokens,
                                 lease_seconds=0.5) for _ in range(4)]
        runner = HttpServiceRunner(backends).start()
        tok = tokens.issue("campaign")
        try:
            res = run_campaign(
                _objective,
                study_spec={
                    "name": f"campaign-{n_workers}-{failure_rate}-{batch_size}",
                    "properties": {"x": suggestions.uniform(-1, 1),
                                   "y": suggestions.uniform(-1, 1)},
                    "sampler": {"name": "tpe"},
                    "pruner": {"name": "median", "n_warmup_steps": 2},
                },
                transport_factory=lambda: HttpTransport(runner.host,
                                                        runner.port),
                token=tok, n_workers=n_workers, n_trials=n_trials,
                failure_rate=failure_rate, stagger_seconds=0.01,
                batch_size=batch_size, seed=5)
            # give the lease sweeper a chance to requeue orphans
            import time
            time.sleep(0.8)
            backends[0].sweep_expired()
        finally:
            runner.stop()
        rows.append({"workers": n_workers, "failure_rate": failure_rate,
                     "batch": batch_size, "trials": res.n_trials,
                     "completed": res.n_completed, "failed": res.n_failed,
                     "pruned": res.n_pruned,
                     "best_loss": None if res.best_value is None
                     else round(res.best_value, 5),
                     "wall_s": round(res.wall_seconds, 2)})
    return rows
