"""PR 4 claim — durability is cheap and recovery is O(new work).

Two tables, emitted together as ``BENCH_storage.json``:

* ``tell_throughput`` — storage mutations/sec per backend and fsync
  mode.  ``group`` batches many acknowledgements into one fsync per
  commit window, so it should sit near ``off`` while ``always`` pays a
  (group-committed) fsync on the ack path.

* ``recovery`` — restart time vs WAL history length under a *bounded*
  live state (a fixed window of running trials receiving intermediate
  re-reports: the WAL grows, the state does not — the shape of a
  long-running campaign with heartbeats).  The legacy single-file
  journal and the engine without compaction replay the whole lifetime,
  so their recovery grows linearly with history.  The engine with
  compaction loads the latest snapshot (bounded by *state* size) and
  replays only the unfolded tail (bounded by *segment* size): restart
  time stays flat as history grows.

Acceptance: at the longest history, compacted-engine recovery beats the
legacy journal by a wide margin and stays within ~2x of its own
shortest-history recovery (flat), while legacy grows with history.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

from repro.core.durable import DurableStorage
from repro.core.storage import InMemoryStorage, JournalStorage
from repro.core.types import StudyConfig, TrialState

PROPS = {"x": {"type": "uniform", "low": 0.0, "high": 1.0},
         "y": {"type": "uniform", "low": 0.0, "high": 1.0}}

SEGMENT_BYTES = 32 * 1024          # small segments: visible rotation


def _make(kind: str, root: str):
    if kind == "memory":
        return InMemoryStorage()
    if kind == "journal":
        return JournalStorage(os.path.join(root, "journal.jsonl"))
    # "durable-<fsync mode>"
    return DurableStorage(os.path.join(root, "engine"),
                          fsync=kind.split("-", 1)[1],
                          segment_bytes=SEGMENT_BYTES, auto_compact=False)


def _bench_throughput(n_trials: int) -> list[dict]:
    rows = []
    for kind in ("memory", "journal", "durable-off", "durable-group",
                 "durable-always"):
        root = tempfile.mkdtemp(prefix="bench-storage-")
        try:
            storage = _make(kind, root)
            study, _ = storage.get_or_create_study(
                StudyConfig(name="thr", properties=PROPS))
            t0 = time.perf_counter()
            for i in range(n_trials):
                t = storage.add_trial(study.key,
                                      {"x": i * 1e-4, "y": 0.5}, None, None)
                storage.update_trial(t.uid, value=float(i % 17),
                                     state=TrialState.COMPLETED,
                                     lease_deadline=None)
            wall = time.perf_counter() - t0
            stats = storage.storage_stats()
            storage.close()
            mutations = 2 * n_trials
            rows.append({
                "scenario": "tell_throughput", "backend": kind,
                "records": mutations, "wall_ms": round(wall * 1e3, 2),
                "mutations_per_s": round(mutations / wall),
                "fsyncs": stats.get("fsyncs", 0),
                "replayed_records": "",
            })
        finally:
            shutil.rmtree(root, ignore_errors=True)
    return rows


def _churn(storage, history: int, window: int = 32, steps: int = 8) -> None:
    """Bounded state, unbounded WAL: ``window`` running trials receive
    ``history`` intermediate re-reports cycling over ``steps`` steps."""
    study, _ = storage.get_or_create_study(
        StudyConfig(name="churn", properties=PROPS))
    far = time.time() + 10_000.0
    uids = [storage.add_trial(study.key, {"x": 0.1 * i, "y": 0.5},
                              f"w{i}", far).uid
            for i in range(window)]
    for i in range(history):
        storage.update_trial(uids[i % window],
                             intermediate=(i // window % steps,
                                           float(i % 101)))


def _bench_recovery(histories: tuple[int, ...]) -> list[dict]:
    rows = []
    for history in histories:
        for kind in ("journal", "durable-nocompact", "durable-compact"):
            root = tempfile.mkdtemp(prefix="bench-storage-")
            try:
                if kind == "journal":
                    storage = _make("journal", root)
                else:
                    storage = _make("durable-off", root)
                _churn(storage, history)
                if kind == "durable-compact":
                    storage.compact(min_segments=1)
                digest = storage.state_digest()
                storage.close()

                t0 = time.perf_counter()
                if kind == "journal":
                    recovered = JournalStorage(
                        os.path.join(root, "journal.jsonl"))
                    replayed = history + 1 + 32     # every record, ever
                else:
                    recovered = DurableStorage(
                        os.path.join(root, "engine"), fsync="off",
                        segment_bytes=SEGMENT_BYTES, auto_compact=False)
                    replayed = recovered.last_recovery["records_replayed"]
                wall = time.perf_counter() - t0
                assert recovered.state_digest() == digest, \
                    f"recovery diverged for {kind}@{history}"
                recovered.close()
                rows.append({
                    "scenario": "recovery", "backend": kind,
                    "records": history,
                    "wall_ms": round(wall * 1e3, 2),
                    "mutations_per_s": "", "fsyncs": "",
                    "replayed_records": replayed,
                })
            finally:
                shutil.rmtree(root, ignore_errors=True)
    return rows


def run(smoke: bool = False) -> list[dict]:
    n_thr = 400 if smoke else 2000
    histories = (1500, 6000) if smoke else (5000, 20000, 60000)
    rows = _bench_throughput(n_thr) + _bench_recovery(histories)
    out_dir = "experiments/benchmarks"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_storage.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows
