"""Out-of-process load generator for ``bench_transport``.

Drives N concurrent keep-alive connections doing ask/tell pairs against
a HOPAAS service and prints one JSON result line.  Two properties matter
for honest frontend measurement:

* **Out of process** — an in-process load generator convoys with the
  server on the GIL badly enough to hide a 3x frontend difference
  behind scheduler noise; real campaign workers are remote anyway.
* **Event-loop, not thread-per-connection** — the generator itself must
  scale to 128+ connections on a small host, otherwise *its* thread
  storms become the bottleneck and compress whatever ratio the server
  side actually has.  Each connection is a tiny state machine
  (write ask -> read ask -> write tell -> read tell), all driven by one
  ``selectors`` loop; stdlib only, starts in milliseconds.

Protocol with the parent (``bench_transport._contended``):

  1. parent starts this script with the target/load on argv;
  2. the script connects every socket and runs ``--warmup`` untimed
     pairs per client (connection + study-context warmup), then prints
     ``READY`` and pauses;
  3. the parent writes one ``GO`` line to stdin (the start barrier);
  4. the script runs the measured load and prints ``{"wall_s": ...,
     "lat_ms": [...]}`` — per-pair latencies in milliseconds.
"""
from __future__ import annotations

import argparse
import json
import selectors
import socket
import sys
import time

_ASK_BODY = b'{"worker_id":"bench"}'
_TELL_BODY = b'{"value":0.125,"state":"completed"}'


class _Client:
    """One keep-alive connection cycling through ask/tell pairs."""

    __slots__ = ("sock", "ask_req", "tell_tail", "pairs_left",
                 "warmup_left", "reading", "outbuf", "inbuf", "t0",
                 "lat_ms", "awaiting_tell")

    def __init__(self, host: str, port: int, ask_req: bytes,
                 tell_tail: bytes, pairs: int, warmup: int):
        self.sock = socket.create_connection((host, port), timeout=300)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.sock.setblocking(False)
        self.ask_req = ask_req
        self.tell_tail = tell_tail
        self.pairs_left = pairs
        self.warmup_left = warmup
        self.reading = False
        self.awaiting_tell = False
        self.outbuf = b""
        self.inbuf = b""
        self.t0 = 0.0
        self.lat_ms: list[float] = []

    def start_pair(self) -> None:
        self.t0 = time.perf_counter()
        self.outbuf = self.ask_req
        self.awaiting_tell = False
        self.reading = False

    def _response(self) -> tuple[int, bytes] | None:
        buf = self.inbuf
        end = buf.find(b"\r\n\r\n")
        if end < 0:
            return None
        i = buf.find(b"Content-Length:", 0, end)
        length = int(buf[i + 15:buf.index(b"\r\n", i)])
        total = end + 4 + length
        if len(buf) < total:
            return None
        self.inbuf = buf[total:]
        return int(buf[9:12]), buf[end + 4:total]

    def on_readable(self) -> str | None:
        """Advance the state machine -> None | "paused" | "done"."""
        chunk = self.sock.recv(65536)
        if not chunk:
            raise ConnectionError("server closed mid-response")
        self.inbuf += chunk
        out = self._response()
        if out is None:
            return None
        status, body = out
        if status != 200:
            raise RuntimeError(f"-> {status}: {body!r}")
        if not self.awaiting_tell:              # ask response: send tell
            uid = json.loads(body)["uid"]
            self.outbuf = (b"POST /api/v2/trials/" + uid.encode()
                           + b":tell" + self.tell_tail)
            self.awaiting_tell = True
            self.reading = False
            return None
        # tell response: pair complete
        self.lat_ms.append((time.perf_counter() - self.t0) * 1e3)
        self.pairs_left -= 1
        if self.pairs_left == 0:
            return "done"
        if self.warmup_left:
            self.warmup_left -= 1
            if self.warmup_left == 0:
                return "paused"                 # hold for the GO barrier
        self.start_pair()
        return None

    def on_writable(self) -> None:
        try:
            sent = self.sock.send(self.outbuf)
        except (BlockingIOError, InterruptedError):
            return
        self.outbuf = self.outbuf[sent:]
        if not self.outbuf:
            self.reading = True


def _drive(sel: selectors.DefaultSelector, interest: dict) -> list[_Client]:
    """One selector round; returns clients that paused or finished
    (already unregistered)."""
    retired = []
    for key, _events in sel.select(30):
        c: _Client = key.data
        state = None
        if interest[c] == selectors.EVENT_WRITE:
            c.on_writable()
        else:
            state = c.on_readable()
            if state is None and c.outbuf:
                c.on_writable()                 # opportunistic send
        if state is not None:
            sel.unregister(c.sock)
            del interest[c]
            retired.append(c)
            if state == "done":
                c.sock.close()
            continue
        want = selectors.EVENT_READ if c.reading else selectors.EVENT_WRITE
        if want != interest[c]:
            sel.modify(c.sock, want, c)
            interest[c] = want
    return retired


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default=None)
    ap.add_argument("--port", type=int, default=None)
    ap.add_argument("--targets", default=None,
                    help="comma-separated host:port endpoints; client i "
                         "connects to target (offset+i) %% len(targets).  "
                         "With the shard fabric's per-worker ports this "
                         "sends each client straight to one worker "
                         "(alternative to --host/--port)")
    ap.add_argument("--token", required=True)
    ap.add_argument("--keys", required=True,
                    help="comma-separated study keys to spread load over")
    ap.add_argument("--clients", type=int, required=True)
    ap.add_argument("--pairs", type=int, required=True,
                    help="measured ask/tell pairs per client")
    ap.add_argument("--offset", type=int, default=0,
                    help="global client index of this process's first "
                         "client (study assignment stays balanced)")
    ap.add_argument("--warmup", type=int, default=2,
                    help="untimed pairs per client before READY")
    args = ap.parse_args()
    keys = args.keys.split(",")
    if args.targets:
        targets = []
        for spec in args.targets.split(","):
            host, _, port = spec.rpartition(":")
            targets.append((host, int(port)))
    elif args.host and args.port:
        targets = [(args.host, args.port)]
    else:
        ap.error("provide --targets or --host/--port")

    common = (f"Host: bench\r\nAuthorization: Bearer {args.token}\r\n"
              "Content-Type: application/json\r\n").encode()
    tell_tail = b" HTTP/1.1\r\n" + common + \
        (f"Content-Length: {len(_TELL_BODY)}\r\n\r\n").encode() + _TELL_BODY

    clients = []
    for i in range(args.clients):
        key = keys[(args.offset + i) % len(keys)]
        # key and target use the same client index modulus, so a parent
        # that aligns keys[j] with targets[j % len(targets)] pins every
        # client to the worker that owns its study
        host, port = targets[(args.offset + i) % len(targets)]
        ask_req = (f"POST /api/v2/studies/{key}/trials:ask "
                   "HTTP/1.1\r\n").encode() + common + \
            (f"Content-Length: {len(_ASK_BODY)}\r\n\r\n").encode() + _ASK_BODY
        clients.append(_Client(host, port, ask_req, tell_tail,
                               args.pairs + args.warmup, args.warmup))

    sel = selectors.DefaultSelector()
    interest: dict[_Client, int] = {}
    try:
        if args.warmup:
            for c in clients:
                c.start_pair()
                sel.register(c.sock, selectors.EVENT_WRITE, c)
                interest[c] = selectors.EVENT_WRITE
            paused = 0
            while paused < len(clients):
                paused += len(_drive(sel, interest))
            for c in clients:
                c.lat_ms.clear()

        print("READY", flush=True)
        if sys.stdin.readline().strip() != "GO":
            return 2
        t0 = time.perf_counter()
        for c in clients:
            c.start_pair()
            sel.register(c.sock, selectors.EVENT_WRITE, c)
            interest[c] = selectors.EVENT_WRITE
        live = len(clients)
        while live:
            live -= sum(1 for _ in _drive(sel, interest))
        wall = time.perf_counter() - t0
    except (RuntimeError, OSError, ConnectionError) as e:
        print(json.dumps({"errors": [repr(e)]}), flush=True)
        return 1
    print(json.dumps({"wall_s": wall,
                      "lat_ms": [x for c in clients for x in c.lat_ms]}),
          flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
