"""Paper sec. 1/2 — Bayesian optimization "focuses on promising regions":
best-found-value vs trial budget for every sampler backend on standard
test functions.  TPE (the Optuna default the paper deploys) must beat
random search.

Columns: function, sampler, trials, best(median over seeds), vs_random.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.auth import TokenManager
from repro.core.client import Client, Study, suggestions
from repro.core.server import HopaasServer
from repro.core.transport import DirectTransport

FUNCS = {
    "branin": {
        "space": {"x": suggestions.uniform(-5.0, 10.0),
                  "y": suggestions.uniform(0.0, 15.0)},
        "f": lambda p: (p["y"] - 5.1 / (4 * math.pi ** 2) * p["x"] ** 2
                        + 5 / math.pi * p["x"] - 6) ** 2
        + 10 * (1 - 1 / (8 * math.pi)) * math.cos(p["x"]) + 10,
        "optimum": 0.397887,
    },
    "rosenbrock2d": {
        "space": {"x": suggestions.uniform(-2.0, 2.0),
                  "y": suggestions.uniform(-1.0, 3.0)},
        "f": lambda p: (1 - p["x"]) ** 2 + 100 * (p["y"] - p["x"] ** 2) ** 2,
        "optimum": 0.0,
    },
    "logspace-quad": {
        "space": {"lr": suggestions.loguniform(1e-6, 1e0)},
        "f": lambda p: (math.log10(p["lr"]) + 3.0) ** 2,   # best at 1e-3
        "optimum": 0.0,
    },
}

SAMPLERS = ["random", "quasirandom", "tpe", "gp", "cmaes"]


def _best_after(sampler: str, fname: str, n_trials: int, seed: int) -> float:
    spec = FUNCS[fname]
    server = HopaasServer(tokens=TokenManager(), seed=seed)
    tok = server.tokens.issue("bench")
    client = Client(DirectTransport(server), tok)
    study = Study(name=f"{fname}-{sampler}-{seed}", properties=spec["space"],
                  sampler={"name": sampler}, client=client)
    best = float("inf")
    for _ in range(n_trials):
        with study.trial() as t:
            t.loss = spec["f"](t.params)
            best = min(best, t.loss)
    return best


def run(n_trials: int = 48, n_seeds: int = 3) -> list[dict]:
    rows = []
    for fname in FUNCS:
        base = None
        for sampler in SAMPLERS:
            vals = [_best_after(sampler, fname, n_trials, s)
                    for s in range(n_seeds)]
            med = float(np.median(vals))
            if sampler == "random":
                base = med
            rows.append({"function": fname, "sampler": sampler,
                         "trials": n_trials,
                         "best_median": round(med, 5),
                         "vs_random": round(base / max(med, 1e-12), 2)})
    return rows
