"""Paper sec. 3 — service architecture: API latency/throughput across
transports, horizontal scaling (Uvicorn x N behind the proxy role), the
sharded-core scenarios (contended multi-study load, batched ask/tell),
and the wire-layer overhead of the typed v2 surface vs the v1 shim
(router + schema validation cost per request).

Columns: scenario, transport, workers, requests, wall_s, req_per_s,
trials_per_s.  ``trials_per_s`` is the ask+tell pair throughput — the
number campaigns actually feel.
"""
from __future__ import annotations

import threading
import time

from repro.core.auth import TokenManager
from repro.core.client import Client, Study, suggestions
from repro.core.server import HopaasServer
from repro.core.storage import InMemoryStorage
from repro.core.transport import (DirectTransport, HttpServiceRunner,
                                  HttpTransport, RoundRobinTransport)


def _row(scenario: str, transport: str, workers: int, requests: int,
         wall: float, n_trials: int) -> dict:
    return {"scenario": scenario, "transport": transport, "workers": workers,
            "requests": requests, "wall_s": round(wall, 3),
            "req_per_s": round(requests / wall, 1),
            "trials_per_s": round(n_trials / wall, 1)}


def _drive(transport, token, n_trials: int) -> float:
    client = Client(transport, token)
    study = Study(name="bench-api",
                  properties={"x": suggestions.uniform(0.0, 1.0)},
                  sampler={"name": "random"}, client=client)
    t0 = time.time()
    for _ in range(n_trials):
        with study.trial() as t:
            t.loss = (t.x - 0.3) ** 2
    return time.time() - t0


def _drive_v1(transport, token, n_trials: int) -> float:
    """The same ask/tell loop through the v1 compat shim (token in path,
    spec inline on every ask) — the pre-v2 wire protocol."""
    client = Client(transport, token)
    spec = {"name": "bench-api-v1",
            "properties": {"x": suggestions.uniform(0.0, 1.0)},
            "sampler": {"name": "random"}}
    t0 = time.time()
    for _ in range(n_trials):
        trial = client._post("ask", dict(spec))
        value = (trial["properties"]["x"] - 0.3) ** 2
        client._post("tell", {"trial_uid": trial["trial_uid"],
                              "value": value})
    return time.time() - t0


def _drive_contended(transport_factory, token, *, n_client_workers: int,
                     n_studies: int, trials_per_worker: int,
                     batch_size: int = 1) -> tuple[float, int]:
    """8-workers-x-4-studies style load: each client thread hammers one of
    ``n_studies`` studies.  Returns (wall_s, request_count)."""
    requests = [0] * n_client_workers

    def worker(widx: int) -> None:
        client = Client(transport_factory(), token, worker_id=f"w{widx}")
        study = Study(name=f"bench-multi-{widx % n_studies}",
                      properties={"x": suggestions.uniform(0.0, 1.0)},
                      sampler={"name": "random"}, client=client)
        done = 0
        while done < trials_per_worker:
            k = min(batch_size, trials_per_worker - done)
            if batch_size > 1:
                trials = study.ask_batch(k)
                study.tell_batch([(t, (t.x - 0.3) ** 2) for t in trials])
                requests[widx] += 2
            else:
                with study.trial() as t:
                    t.loss = (t.x - 0.3) ** 2
                requests[widx] += 2
            done += k

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_client_workers)]
    t0 = time.time()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.time() - t0, sum(requests)


def run(n_trials: int = 200, smoke: bool = False) -> list[dict]:
    if smoke:
        n_trials = 40
    rows = []
    tokens = TokenManager()
    tok = tokens.issue("bench")

    # -- single-study latency across transports -------------------------
    server = HopaasServer(storage=InMemoryStorage(), tokens=tokens)
    dt = _drive(DirectTransport(server), tok, n_trials)
    rows.append(_row("single-study", "direct", 1, 2 * n_trials, dt, n_trials))

    storage = InMemoryStorage()
    workers = [HopaasServer(storage=storage, tokens=tokens) for _ in range(4)]
    dt = _drive(RoundRobinTransport(workers), tok, n_trials)
    rows.append(_row("single-study", "round-robin", 4, 2 * n_trials, dt,
                     n_trials))

    # real HTTP (the wire the paper uses), 1 and 4 backend workers
    for n_workers in (1, 4):
        storage = InMemoryStorage()
        workers = [HopaasServer(storage=storage, tokens=tokens)
                   for _ in range(n_workers)]
        runner = HttpServiceRunner(workers).start()
        try:
            dt = _drive(HttpTransport(runner.host, runner.port), tok,
                        n_trials)
        finally:
            runner.stop()
        rows.append(_row("single-study", "http", n_workers, 2 * n_trials, dt,
                         n_trials))

    # -- persistent connection vs reconnect-per-request ------------------
    for persistent, label in ((False, "http-reconnect"), (True, "http-keepalive")):
        storage = InMemoryStorage()
        runner = HttpServiceRunner(
            [HopaasServer(storage=storage, tokens=tokens)]).start()
        try:
            dt = _drive(HttpTransport(runner.host, runner.port,
                                      persistent=persistent), tok, n_trials)
        finally:
            runner.stop()
        rows.append(_row("single-study", label, 1, 2 * n_trials, dt, n_trials))

    # -- wire-layer overhead: v1 shim vs typed v2, same core -------------
    # DirectTransport isolates the router + schema-validation cost from
    # socket noise; HTTP shows what real clients see.
    for label, driver in (("direct-v1", _drive_v1), ("direct-v2", _drive)):
        server = HopaasServer(storage=InMemoryStorage(), tokens=tokens)
        dt = driver(DirectTransport(server), tok, n_trials)
        rows.append(_row("proto-overhead", label, 1, 2 * n_trials, dt,
                         n_trials))
    for label, driver in (("http-v1", _drive_v1), ("http-v2", _drive)):
        storage = InMemoryStorage()
        runner = HttpServiceRunner(
            [HopaasServer(storage=storage, tokens=tokens)]).start()
        try:
            dt = driver(HttpTransport(runner.host, runner.port), tok,
                        n_trials)
        finally:
            runner.stop()
        rows.append(_row("proto-overhead", label, 1, 2 * n_trials, dt,
                         n_trials))

    # -- contended multi-study load: 8 client workers x 4 studies --------
    n_client_workers, n_studies = 8, 4
    per_worker = max(5, n_trials // n_client_workers)
    total = n_client_workers * per_worker
    for batch_size, label in ((1, "http"), (8, "http+batch")):
        storage = InMemoryStorage()
        backends = [HopaasServer(storage=storage, tokens=tokens)
                    for _ in range(4)]
        runner = HttpServiceRunner(backends).start()
        try:
            wall, requests = _drive_contended(
                lambda: HttpTransport(runner.host, runner.port), tok,
                n_client_workers=n_client_workers, n_studies=n_studies,
                trials_per_worker=per_worker, batch_size=batch_size)
        finally:
            runner.stop()
        rows.append(_row(f"contended-{n_client_workers}w-{n_studies}s",
                         label, 4, requests, wall, total))
    return rows
