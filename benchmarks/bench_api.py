"""Paper sec. 3 — service architecture: API latency/throughput across
transports and horizontal scaling (Uvicorn x N behind the proxy role).

Columns: transport, workers, requests, wall_s, req_per_s.
"""
from __future__ import annotations

import time

from repro.core.auth import TokenManager
from repro.core.client import Client, Study, suggestions
from repro.core.server import HopaasServer
from repro.core.storage import InMemoryStorage
from repro.core.transport import (DirectTransport, HttpServiceRunner,
                                  HttpTransport, RoundRobinTransport)


def _drive(transport, token, n_trials: int) -> float:
    client = Client(transport, token)
    study = Study(name="bench-api",
                  properties={"x": suggestions.uniform(0.0, 1.0)},
                  sampler={"name": "random"}, client=client)
    t0 = time.time()
    for _ in range(n_trials):
        with study.trial() as t:
            t.loss = (t.x - 0.3) ** 2
    return time.time() - t0


def run(n_trials: int = 200) -> list[dict]:
    rows = []
    tokens = TokenManager()
    tok = tokens.issue("bench")

    # in-process
    server = HopaasServer(storage=InMemoryStorage(), tokens=tokens)
    dt = _drive(DirectTransport(server), tok, n_trials)
    rows.append({"transport": "direct", "workers": 1, "requests": 2 * n_trials,
                 "wall_s": round(dt, 3), "req_per_s": round(2 * n_trials / dt, 1)})

    # in-process, 4 workers round-robin on shared storage
    storage = InMemoryStorage()
    workers = [HopaasServer(storage=storage, tokens=tokens) for _ in range(4)]
    dt = _drive(RoundRobinTransport(workers), tok, n_trials)
    rows.append({"transport": "round-robin", "workers": 4,
                 "requests": 2 * n_trials, "wall_s": round(dt, 3),
                 "req_per_s": round(2 * n_trials / dt, 1)})

    # real HTTP (the wire the paper uses), 1 and 4 backend workers
    for n_workers in (1, 4):
        storage = InMemoryStorage()
        workers = [HopaasServer(storage=storage, tokens=tokens)
                   for _ in range(n_workers)]
        runner = HttpServiceRunner(workers).start()
        try:
            dt = _drive(HttpTransport(runner.host, runner.port), tok,
                        n_trials)
        finally:
            runner.stop()
        rows.append({"transport": "http", "workers": n_workers,
                     "requests": 2 * n_trials, "wall_s": round(dt, 3),
                     "req_per_s": round(2 * n_trials / dt, 1)})
    return rows
