"""PR 10 claim — the speculative ask pipeline pays off under contention.

Two tables, emitted together as ``BENCH_parallel_ask.json``:

* **throughput** — C contended clients (64 / 256) hammer one TPE study
  with a 2k-trial history in a closed ask→tell loop, against (a) the
  inline baseline (every proposal computed under the shard lock) and
  (b) the speculative pipeline (``speculate_depth=64``: proposals
  precomputed off-lock by the background worker, the ask path drains
  the version-tagged queue).  The acceptance metric is **contended ask
  throughput**: each thread clocks its time inside ``op_ask``, and
  ``ask_ops_s = clients / mean_ask_latency`` — the rate the fleet
  sustains on the ask path itself (lock wait + drain-or-sample +
  journaled registration).  The closed-loop pair rate (``pair_ops_s``)
  is reported alongside for context; it is bounded by the tell cost,
  which is common to both modes and not what this pipeline optimizes.
  Rows also record the queue hit rate (``hits + stale_hits`` over all
  drains).  Acceptance: 256-client speculative ask throughput >= 3x
  inline.

* **quality** — constant-liar batched ask must not cost convergence:
  on a 3-d shifted sphere (optimum value 1.0), 16-way batched rounds
  with ``liar=mean`` get the same trial budget as a strictly sequential
  ask/tell loop.  Acceptance: the batched best is within 10% of the
  sequential best (median over seeds).

Smoke mode shrinks the history, client counts, and budgets so the CI
run finishes in seconds; the acceptance columns are still emitted.
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from repro.core.auth import TokenManager
from repro.core.server import HopaasServer
from repro.core.types import TrialState

PROPS = {"lr": {"type": "loguniform", "low": 1e-5, "high": 1e-1},
         "wd": {"type": "loguniform", "low": 1e-6, "high": 1e-2},
         "width": {"type": "int", "low": 32, "high": 1024},
         "dropout": {"type": "uniform", "low": 0.0, "high": 0.5}}

SPHERE_SHIFT = (0.62, 0.31, 0.47)          # optimum inside the unit cube


def _sphere(params: dict) -> float:
    return 1.0 + sum((params[f"x{i}"] - o) ** 2
                     for i, o in enumerate(SPHERE_SHIFT))


def _make_server(history: int, depth: int, seed: int = 7) -> tuple:
    """One server + one TPE study prefilled with ``history`` completed
    trials (written straight through storage, like a recovered WAL —
    the observation cache picks them up on the first ask)."""
    server = HopaasServer(tokens=TokenManager(), seed=seed,
                          speculate_depth=depth)
    _, study = server.op_create_study({
        "name": f"parallel-ask-{history}-{depth}",
        "properties": PROPS,
        "sampler": {"name": "tpe", "n_startup_trials": 10, "liar": "mean"}})
    key = study["key"]
    space = server._context_for_key(key).space
    rng = np.random.default_rng(seed)
    for _ in range(history):
        t = server.storage.add_trial(key, space.sample_uniform(rng),
                                     None, None)
        server.storage.update_trial(t.uid, value=float(rng.uniform(0, 10)),
                                    state=TrialState.COMPLETED,
                                    lease_deadline=None)
    return server, key


def _hammer(server: HopaasServer, key: str, clients: int,
            duration: float) -> tuple[int, float, float]:
    """Closed-loop contended ask->tell from ``clients`` threads; returns
    (completed ask+tell pairs, elapsed seconds, total seconds the
    threads spent inside ``op_ask``)."""
    ops = [0] * clients
    ask_time = [0.0] * clients
    start = threading.Barrier(clients + 1)
    stop = threading.Event()

    def worker(i: int) -> None:
        rng = np.random.default_rng(1000 + i)
        start.wait()
        while not stop.is_set():
            t0 = time.perf_counter()
            (trial,) = server.op_ask(key, f"w{i}", 1, parallelism=clients)
            ask_time[i] += time.perf_counter() - t0
            server.op_tell(trial["uid"], float(rng.uniform(0, 10)),
                           "completed")
            ops[i] += 1

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(clients)]
    for t in threads:
        t.start()
    start.wait()
    t0 = time.perf_counter()
    time.sleep(duration)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    return sum(ops), time.perf_counter() - t0, sum(ask_time)


def _throughput_rows(smoke: bool) -> list[dict]:
    history = 300 if smoke else 2000
    duration = 0.8 if smoke else 3.0
    warmup = 0.3 if smoke else 1.0
    client_counts = (16,) if smoke else (64, 256)
    depth = 64
    rows = []
    for clients in client_counts:
        results = {}
        for mode, spec_depth in (("inline", 0), ("speculative", depth)):
            server, key = _make_server(history, spec_depth)
            try:
                # warm phase: pay jit compiles / buffer growth off the
                # clock so run order doesn't bias the comparison, then
                # measure counter deltas only
                _hammer(server, key, clients, warmup)
                before = server.speculation_stats()
                done, elapsed, ask_s = _hammer(server, key, clients,
                                               duration)
                after = server.speculation_stats()
                stats = {k: (after[k] - before[k]
                             if isinstance(after[k], int)
                             and not isinstance(after[k], bool) else after[k])
                         for k in after}
                # contended ask throughput: the rate the fleet sustains
                # on the ask path alone (clients / mean ask latency) —
                # the closed loop alternates ask and tell, and the tell
                # leg is identical in both modes
                ask_rate = done * clients / max(ask_s, 1e-9)
                results[mode] = (ask_rate, done / elapsed, stats)
            finally:
                server.close()
        base_ask, base_pair, _ = results["inline"]
        spec_ask, spec_pair, spec_stats = results["speculative"]
        drains = (spec_stats["hits"] + spec_stats["stale_hits"]
                  + spec_stats["misses"])
        hit_rate = ((spec_stats["hits"] + spec_stats["stale_hits"])
                    / max(drains, 1))
        rows.append({
            "table": "throughput", "clients": clients, "history": history,
            "inline_ask_ops_s": round(base_ask, 1),
            "speculative_ask_ops_s": round(spec_ask, 1),
            "ask_speedup": round(spec_ask / max(base_ask, 1e-9), 2),
            "inline_pair_ops_s": round(base_pair, 1),
            "speculative_pair_ops_s": round(spec_pair, 1),
            "pair_speedup": round(spec_pair / max(base_pair, 1e-9), 2),
            "queue_hit_rate": round(hit_rate, 3),
            "stale_hits": spec_stats["stale_hits"],
            "precompute_rounds": spec_stats["rounds"],
        })
    return rows


def _best_sequential(budget: int, seed: int) -> float:
    server, key = _quality_server(seed)
    try:
        best = float("inf")
        for _ in range(budget):
            (trial,) = server.op_ask(key, "seq", 1)
            v = _sphere(trial["params"])
            server.op_tell(trial["uid"], v, "completed")
            best = min(best, v)
        return best
    finally:
        server.close()


def _best_batched(budget: int, batch: int, seed: int) -> float:
    server, key = _quality_server(seed)
    try:
        best = float("inf")
        for _ in range(budget // batch):
            trials = server.op_ask(key, "batch", batch)
            # evaluate the whole wave before any tell lands — the
            # constant-liar rows are all that keeps the batch diverse
            values = [_sphere(t["params"]) for t in trials]
            for t, v in zip(trials, values):
                server.op_tell(t["uid"], v, "completed")
                best = min(best, v)
        return best
    finally:
        server.close()


def _quality_server(seed: int) -> tuple:
    server = HopaasServer(tokens=TokenManager(), seed=seed)
    _, study = server.op_create_study({
        "name": f"sphere-{seed}",
        "properties": {f"x{i}": {"type": "uniform", "low": 0.0, "high": 1.0}
                       for i in range(len(SPHERE_SHIFT))},
        "sampler": {"name": "tpe", "n_startup_trials": 8, "liar": "mean"}})
    return server, study["key"]


def _quality_rows(smoke: bool) -> list[dict]:
    budget, batch = (32, 8) if smoke else (96, 16)
    seeds = (3,) if smoke else (3, 5, 11)
    seq = [_best_sequential(budget, s) for s in seeds]
    bat = [_best_batched(budget, batch, s) for s in seeds]
    seq_med = float(np.median(seq))
    bat_med = float(np.median(bat))
    # the sphere floor is 1.0, so the ratio of bests is well-conditioned
    return [{
        "table": "quality", "budget": budget, "batch": batch,
        "seeds": len(seeds),
        "sequential_best": round(seq_med, 4),
        "batched_best": round(bat_med, 4),
        "ratio": round(bat_med / seq_med, 4),
        "within_10pct": bool(bat_med <= 1.10 * seq_med),
    }]


def run(smoke: bool = False) -> list[dict]:
    rows = _throughput_rows(smoke) + _quality_rows(smoke)
    out_dir = "experiments/benchmarks"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_parallel_ask.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows
