"""PR 7 — replicated durable shards: the cost of surviving a dead leader.

Replication ships each leader's WAL stream to follower processes
(``repro.core.replication``); ``semisync`` additionally makes the fsync
ack wait for one follower ack.  This table prices both against the
unreplicated fabric and measures the thing replication buys — the
availability gap across an automatic failover.  Emitted as
``BENCH_replication.json``:

* ``repl-{off,async,semisync}`` — 2-worker durable fabric (group fsync),
  16 keep-alive clients driving ask/tell pairs through the router, with
  replicas=0 / 1 follower per shard (async) / 1 follower (semisync).
* ``failover-gap`` — under the same async-replicated fabric, SIGKILL
  one shard leader mid-load and record the observed gap: the span from
  the kill to the first completed ask/tell pair against that shard
  after promotion (single client, patient retry).

Acceptance (ISSUE 7): async overhead within ~10% of unreplicated, and
the measured failover gap under the 5 s budget.  Every row records
``cores`` — replication doubles the process count, so on hosts with
fewer cores than processes the follower replay time-shares the
leaders' cores and the overhead compresses the throughput ratio well
past 10%; the honest async-overhead signal needs >= 4 cores.

Columns: scenario, workers, replicas, clients, requests, wall_s,
pairs_per_s, p50_ms, p99_ms, gap_s, cores.
"""
from __future__ import annotations

import json
import os
import signal
import sys
import time

from repro.core.client import Client, RetryPolicy, Study, suggestions
from repro.core.fabric import ShardFabric
from repro.core.transport import HttpTransport

from benchmarks.bench_fabric import _aligned_keys, _load

_SPACE = {"x": suggestions.uniform(0.0, 1.0)}


def _row(scenario: str, replicas: int, clients: int, requests: int | None,
         wall: float | None, pairs: int | None, lats_ms: list[float] | None,
         gap_s: float | None = None) -> dict:
    lats = sorted(lats_ms or [])
    return {"scenario": scenario, "workers": 2, "replicas": replicas,
            "clients": clients, "requests": requests,
            "wall_s": None if wall is None else round(wall, 3),
            "pairs_per_s": (None if not wall
                            else round(pairs / wall, 1)),
            "p50_ms": (None if not lats
                       else round(lats[len(lats) // 2], 2)),
            "p99_ms": (None if not lats
                       else round(lats[min(len(lats) - 1,
                                           int(len(lats) * 0.99))], 2)),
            "gap_s": None if gap_s is None else round(gap_s, 3),
            "cores": os.cpu_count()}


def _throughput(root: str, *, replicas: int, mode: str, n_clients: int,
                pairs_per_client: int) -> dict:
    fab = ShardFabric(workers=2, storage="durable", fsync="group",
                      root=root, replicas=replicas, replication=mode,
                      respawn=False).start()
    try:
        tok = fab.issue_token("bench")
        setup = Client(HttpTransport(fab.host, fab.port), tok)
        keys = _aligned_keys(fab, setup, per_worker=4)
        pairs = pairs_per_client * n_clients
        wall, lats = _load(tok, keys, n_clients=n_clients,
                           pairs_per_client=pairs_per_client,
                           host=fab.host, port=fab.port)
        label = "off" if replicas == 0 else mode
        return _row(f"repl-{label}", replicas, n_clients, 2 * pairs,
                    wall, pairs, lats)
    finally:
        fab.stop()


def _failover_gap(root: str, *, pairs_before: int) -> dict:
    """SIGKILL a shard leader mid-campaign; the gap is the span between
    the kill and the first ask/tell pair completed against that shard
    through the promoted follower."""
    fab = ShardFabric(workers=2, storage="durable", fsync="group",
                      root=root, replicas=1, replication="async",
                      respawn_poll=0.1).start()
    try:
        tok = fab.issue_token("bench")
        patient = RetryPolicy(max_attempts=12, base_delay=0.05,
                              max_delay=0.5)
        cl = Client(HttpTransport(fab.host, fab.port), tok, retry=patient)
        study = Study(name="bench-failover", properties=dict(_SPACE),
                      sampler={"name": "random"}, client=cl)
        key = study._ensure_key()
        for _ in range(pairs_before):
            t = study.ask()
            study.tell(t, value=abs(t.x))

        wid = fab.owner_of(key)
        old_pid = fab._workers[wid].pid
        killed = time.monotonic()
        fab.kill_worker(wid, sig=signal.SIGKILL)
        t = study.ask()
        study.tell(t, value=abs(t.x))
        gap = time.monotonic() - killed
        assert fab.failovers >= 1, "leader death healed without failover"
        return _row("failover-gap", 1, 1, None, None, None, None,
                    gap_s=gap)
    finally:
        fab.stop()


def run(smoke: bool = False) -> list[dict]:
    n_clients = 16
    total_pairs = 256 if smoke else 768
    pairs_per_client = max(2, total_pairs // n_clients)
    base = os.path.join("experiments", "benchmarks",
                        f"_repl_scratch_{os.getpid()}")
    rows: list[dict] = []
    try:
        for i, (replicas, mode) in enumerate(
                [(0, "async"), (1, "async"), (1, "semisync")]):
            rows.append(_throughput(os.path.join(base, f"t{i}"),
                                    replicas=replicas, mode=mode,
                                    n_clients=n_clients,
                                    pairs_per_client=pairs_per_client))
        rows.append(_failover_gap(os.path.join(base, "gap"),
                                  pairs_before=8 if smoke else 32))
    finally:
        import shutil
        shutil.rmtree(base, ignore_errors=True)

    # -- acceptance summary: async replication overhead vs replicas=0 ----
    by = {r["scenario"]: r for r in rows}
    base_tp = by["repl-off"]["pairs_per_s"]
    rows.append({"scenario": "async-overhead", "workers": 2, "replicas": 1,
                 "clients": n_clients, "requests": None, "wall_s": None,
                 "pairs_per_s": round(
                     by["repl-async"]["pairs_per_s"] / base_tp, 3),
                 "p50_ms": None, "p99_ms": None, "gap_s": None,
                 "cores": os.cpu_count()})

    out_dir = "experiments/benchmarks"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_replication.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    print(json.dumps(run(smoke="--smoke" in sys.argv), indent=1))
