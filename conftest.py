"""Repo-root pytest hooks: the opt-in runtime sanitizers.

``REPRO_SANITIZE=1 pytest tests/core`` instruments every lock created
from repro source (see ``repro.analysis.sanitize``), records the real
acquisition order while the suite runs, and at session end cross-checks
it against the static lock-order graph.  An observed order the static
graph can reach in reverse is a potential deadlock and fails the run.

``REPRO_SANITIZE=race`` additionally runs the Eraser-style shared-state
sanitizer: the concurrency-bearing core classes record (thread, field,
held-lockset) samples on every attribute write, and a field observed
written from two threads with an empty lockset intersection fails the
session.
"""
from __future__ import annotations

import os

_MODE = os.environ.get("REPRO_SANITIZE", "")
_SANITIZE = _MODE in ("1", "race")

if _SANITIZE:
    from repro.analysis import sanitize

    sanitize.install()
    if _MODE == "race":
        sanitize.install_race()


def pytest_sessionfinish(session, exitstatus):
    if not _SANITIZE:
        return
    from repro.analysis import sanitize

    out = sanitize.cross_check_repo()
    print(f"\nrepro-sanitize: {len(out['edges'])} lock-order edge(s) "
          f"observed across {sum(out['locks_created'].values())} "
          f"instrumented lock(s)")
    for item in out["unknown"]:
        print(f"repro-sanitize: note: edge {item['edge']} not in the "
              f"static graph (observed at {item['site']})")
    for stall in out["stalls"]:
        print(f"repro-sanitize: STALL: {stall['thread']} waited "
              f"{stall['waited']:.0f}s for {stall['key']}")
    if out["inversions"]:
        for inv in out["inversions"]:
            print(f"repro-sanitize: INVERSION: observed {inv['edge']} "
                  f"at {inv['site']} but the static graph orders "
                  f"{inv['static_reverse_path']}")
        raise RuntimeError(
            f"repro-sanitize: {len(out['inversions'])} lock-order "
            f"inversion(s) against the static graph — potential "
            f"deadlock(s); see the lines above")

    if sanitize.race_installed():
        race = sanitize.race_report()
        print(f"repro-sanitize: race mode tracked "
              f"{race['fields_tracked']} shared field(s) across "
              f"{len(race['instrumented_classes'])} class(es) "
              f"({race['fields_allowed']} audited allow-listed)")
        if race["violations"]:
            for v in race["violations"]:
                print(f"repro-sanitize: RACE: {v['class']}.{v['field']} "
                      f"written by threads {v['threads']} with empty "
                      f"lockset intersection (last write at {v['site']})")
            raise RuntimeError(
                f"repro-sanitize: {len(race['violations'])} shared-state "
                f"race(s) observed — unlocked cross-thread field "
                f"write(s); see the lines above")
