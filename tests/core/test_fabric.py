"""Multi-process shard fabric (PR 6): consistent-hash routing, the WAL
directory lock, digest-verified shard handoff, crash respawn, and the
in-process router mode CI runs the whole suite under."""
import json
import signal
import threading
import time

import pytest

from repro.core import (Client, ClientStudy, DurableStorage, HopaasServer,
                        HttpServiceRunner, HttpTransport, RetryPolicy,
                        ShardFabric, ShardedHttpTransport, TokenManager,
                        WalDirectoryLockedError, suggestions)
from repro.core.fabric import HashRing, RouteTable, classify_target
from repro.core.storage import InMemoryStorage

_SPACE = {"x": suggestions.uniform(-1.0, 1.0)}

# generous retry: fabric tests inject crashes/freezes whose recovery
# (respawn ~1.5s) outlasts the default client backoff
_PATIENT = RetryPolicy(max_attempts=8, base_delay=0.1, max_delay=1.0)


def _client(fab, retry=None):
    tok = fab.issue_token("t")
    return Client(HttpTransport(fab.host, fab.port), tok,
                  retry=retry or _PATIENT), tok


def _study(client, name="fab", sampler="random"):
    return ClientStudy(name=name, client=client, properties=dict(_SPACE),
                       sampler={"name": sampler})


# --------------------------------------------------------------------------- #
# consistent-hash ring + request classification
# --------------------------------------------------------------------------- #
def test_hash_ring_minimal_remap_on_grow():
    keys = [f"study-{i:03d}" for i in range(200)]
    r3 = HashRing([0, 1, 2])
    r4 = HashRing([0, 1, 2, 3])
    moved = [k for k in keys if r3.owner(k) != r4.owner(k)]
    # every moved key must move TO the new worker, never between old ones
    assert moved and all(r4.owner(k) == 3 for k in moved)
    # and roughly 1/4 of the keys move, not a full reshuffle
    assert len(moved) < len(keys) // 2
    # placement is deterministic
    assert [r3.owner(k) for k in keys] == [HashRing([2, 1, 0]).owner(k)
                                           for k in keys]


def test_route_table_overrides_and_flip():
    table = RouteTable({0: ("h", 1), 1: ("h", 2)})
    key = "abc123"
    base = table.owner(key)
    other = 1 - base
    table.update(overrides={key: other})
    assert table.owner(key) == other            # override wins over ring
    table.update(clear_overrides=True)
    assert table.owner(key) == base
    # endpoints can grow before the ring flips: reachability before traffic
    table.update(endpoints={0: ("h", 1), 1: ("h", 2), 2: ("h", 3)},
                 ring_ids=[0, 1])
    assert table.endpoint(2) == ("h", 3)
    assert table.worker_ids() == [0, 1]


def test_classify_target_covers_both_surfaces():
    assert classify_target("POST", "/api/v2/studies/k1/trials:ask") == \
        ("key", "k1")
    assert classify_target("POST", "/api/v2/trials/k1:7:tell") == \
        ("key", "k1")
    assert classify_target("POST", "/api/v2/studies") == ("spec",)
    assert classify_target("GET", "/api/v2/studies?limit=5") == ("gather",)
    assert classify_target("POST", "/api/v2/trials:tell_batch") == \
        ("tell_batch",)
    assert classify_target("POST", "/api/ask/TOKEN") == ("spec",)
    assert classify_target("POST", "/api/tell/TOKEN") == ("uid",)
    assert classify_target("POST", "/api/tell_batch/TOKEN") == \
        ("tell_batch",)
    assert classify_target("GET", "/api/studies/TOKEN") == ("gather",)
    assert classify_target("GET", "/api/version") == ("default",)
    assert classify_target("DELETE", "/api/v2/studies") == ("default",)


# --------------------------------------------------------------------------- #
# satellite: exclusive WAL directory lock
# --------------------------------------------------------------------------- #
def test_wal_directory_lock_excludes_second_opener(tmp_path):
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="off", auto_compact=False)
    with pytest.raises(WalDirectoryLockedError) as e:
        DurableStorage(root, fsync="off")
    assert "locked by another live process" in str(e.value)
    st.close()                                   # close releases the lock
    st2 = DurableStorage(root, fsync="off")
    st2.close()


# --------------------------------------------------------------------------- #
# fabric end-to-end: routing, both API surfaces, scatter-gather
# --------------------------------------------------------------------------- #
def test_fabric_routes_both_surfaces_and_gathers():
    fab = ShardFabric(workers=2, storage="memory").start()
    try:
        cl, tok = _client(fab)
        studies = [_study(cl, name=f"fab-{i}") for i in range(6)]
        for s in studies:
            s._ensure_key()
        locations = fab.locations()
        owned = {w: len(ks) for w, ks in locations.items()}
        assert sum(owned.values()) == 6
        assert len([w for w, n in owned.items() if n]) >= 1

        # v2 ask/tell through the router proxy
        for s in studies[:3]:
            t = s.ask()
            s.tell(t, value=abs(t.x))
        # v1 surface (spec- and uid-keyed bodies)
        ask = cl._post("ask", studies[0]._spec_body())
        tell = cl._post("tell", {"trial_uid": ask["trial_uid"],
                                 "value": 0.5})
        assert tell["state"] == "completed"

        # tell_batch split by owner, results merged back in order
        trials = [s.ask() for s in studies]
        results = cl.tell_batch(
            [{"trial_uid": t.uid, "value": 0.25, "state": "completed"}
             for t in trials])
        assert [r["uid"] for r in results] == [t.uid for t in trials]
        assert all(r["status"] == 200 for r in results)

        # scatter-gather study lists, v2 (paged) and v1
        v2 = {s["name"] for s in cl.studies()}
        assert {f"fab-{i}" for i in range(6)} <= v2
        status, payload, _ = HttpTransport(fab.host, fab.port).request_full(
            "GET", f"/api/studies/{tok}")
        assert status == 200
        assert {s["name"] for s in payload["studies"]} == v2
        # paging is positional across the merged list
        page = cl.trials_page(studies[0].study_key, limit=1)
        assert len(page["trials"]) == 1
        assert fab.stats()["dispatcher"]["proxied"] > 0
    finally:
        fab.stop()


def test_sharded_transport_skips_the_router_hop():
    fab = ShardFabric(workers=2, storage="memory").start()
    try:
        tok = fab.issue_token("t")
        transport = ShardedHttpTransport(fab.endpoints)
        cl = Client(transport, tok, retry=_PATIENT)
        s = _study(cl, name="direct")
        t = s.ask()
        s.tell(t, value=abs(t.x))
        resource = cl.study(s.study_key)
        assert resource["n_completed"] == 1
        # the keyed requests went straight to the owner: no proxying
        assert fab.stats()["dispatcher"]["proxied"] == 0
        transport.close()
    finally:
        fab.stop()


# --------------------------------------------------------------------------- #
# satellite: kill-and-rebalance a live study mid-campaign
# --------------------------------------------------------------------------- #
def test_migration_digest_identical_zero_lost_tells():
    fab = ShardFabric(workers=2, storage="durable", fsync="off",
                      respawn=False).start()
    try:
        cl, _tok = _client(fab)
        study = _study(cl, name="live")
        key = study._ensure_key()
        src = fab.owner_of(key)
        dst = [w for w in fab.locations() if w != src][0]

        stop = threading.Event()
        told, errors = [], []

        def campaign():
            while not stop.is_set():
                try:
                    t = study.ask()
                    study.tell(t, value=abs(t.x))
                    told.append(t.uid)
                except Exception as e:       # pragma: no cover - the assert
                    errors.append(repr(e))
                    return

        threads = [threading.Thread(target=campaign) for _ in range(3)]
        for th in threads:
            th.start()
        time.sleep(0.3)                      # campaign in full flight
        rec1 = fab.migrate(key, src, dst)    # ...and rebalance under it
        time.sleep(0.2)
        rec2 = fab.migrate(key, dst, src)    # and back
        time.sleep(0.2)
        stop.set()
        for th in threads:
            th.join(timeout=30)
        assert not errors, errors

        # 1) both handoffs were digest-verified index-identical
        assert rec1["verified"] and rec2["verified"]
        assert rec1["src_digest"] == rec1["dst_digest"]
        # 2) zero lost tells: every acknowledged tell is a completion
        resource = cl.study(key)
        completed = {t["uid"] for t in cl.iter_trials(key,
                                                      state="completed")}
        assert set(told) <= completed
        # 3) no double-counted completions
        assert resource["n_completed"] == len(completed)
        assert len(told) == len(set(told))
        # the shard now lives where the second migration put it
        locations = fab.locations()
        assert key in locations[src] and key not in locations[dst]
    finally:
        fab.stop()


def test_add_and_remove_worker_rebalances():
    fab = ShardFabric(workers=2, storage="memory", respawn=False).start()
    try:
        cl, _tok = _client(fab)
        studies = [_study(cl, name=f"grow-{i}") for i in range(8)]
        for s in studies:
            s._ensure_key()
            t = s.ask()
            s.tell(t, value=abs(t.x))
        before = {k for ks in fab.locations().values() for k in ks}

        wid = fab.add_worker()
        locations = fab.locations()
        assert set(locations) == {0, 1, wid}
        assert {k for ks in locations.values() for k in ks} == before
        assert all(h["verified"] for h in fab.handoffs)
        # every study still serves reads and writes after the reshuffle
        for s in studies:
            t = s.ask()
            s.tell(t, value=abs(t.x))
            assert cl.study(s.study_key)["n_completed"] == 2

        fab.remove_worker(wid)
        locations = fab.locations()
        assert set(locations) == {0, 1}
        assert {k for ks in locations.values() for k in ks} == before
        assert cl.study(studies[0].study_key)["n_completed"] == 2
    finally:
        fab.stop()


# --------------------------------------------------------------------------- #
# satellite: a hung worker must not hang the router
# --------------------------------------------------------------------------- #
def test_hung_worker_yields_502_not_a_hung_router():
    fab = ShardFabric(workers=2, storage="memory", upstream_timeout=1.0,
                      respawn=False).start()
    try:
        cl, tok = _client(fab)
        study = _study(cl, name="hang")
        key = study._ensure_key()
        owner = fab.owner_of(key)
        # a study on the *other* worker, created before the wedge
        other = next(s for s in (_study(cl, name=f"hang-{i}")
                                 for i in range(20))
                     if fab.owner_of(s._ensure_key()) != owner)
        fab.kill_worker(owner, sig=signal.SIGSTOP)   # wedge, don't die
        try:
            raw = HttpTransport(fab.host, fab.port, timeout=20.0)
            t0 = time.monotonic()
            status, payload, _ = raw.request_full(
                "POST", f"/api/v2/studies/{key}/trials:ask",
                {"worker_id": "t"},
                headers={"Authorization": f"Bearer {tok}"})
            elapsed = time.monotonic() - t0
            assert status == 502, (status, payload)
            assert payload["error"]["code"] == "bad_upstream"
            # bounded by the 1s upstream timeout, not the 20s client one
            # (generous slack: CI boxes time-share the cores)
            assert elapsed < 10.0
            # other workers' studies keep serving while one is wedged
            t = other.ask()
            other.tell(t, value=0.0)
        finally:
            fab.kill_worker(owner, sig=signal.SIGCONT)
        # the un-wedged worker serves again (client retries ride it out)
        t = study.ask()
        study.tell(t, value=abs(t.x))
    finally:
        fab.stop()


# --------------------------------------------------------------------------- #
# crash respawn: digest-verified recovery + lease requeue
# --------------------------------------------------------------------------- #
def test_crashed_worker_respawns_with_state_and_requeues_leases():
    fab = ShardFabric(workers=2, storage="durable", fsync="always",
                      lease_seconds=1.0, respawn_poll=0.1).start()
    try:
        cl, _tok = _client(fab)
        study = _study(cl, name="crash")
        key = study._ensure_key()
        for _ in range(3):
            t = study.ask()
            study.tell(t, value=abs(t.x))
        leased = study.ask()                 # in flight when the crash hits
        wid = fab.owner_of(key)
        pre_digest = fab.worker_digest(wid)  # latest state, fsynced
        old_pid = fab._workers[wid].pid

        fab.kill_worker(wid, sig=signal.SIGKILL)
        wp = fab.wait_respawn(wid, old_pid)
        assert wp.pid != old_pid
        # recovery replayed the WAL to the exact pre-crash state; under
        # REPRO_REPLICAS>0 the same crash is healed by promoting a
        # follower (failover) instead of respawning on the WAL
        assert wp.digest == pre_digest
        event = [e for e in fab.events
                 if e["event"] in ("respawn", "failover")][-1]
        assert event["digest_match"] is True
        assert event["recovery"]["records_replayed"] >= 0

        # the lease taken through the dead worker lapses and is requeued:
        # the same params come back on the next ask
        time.sleep(1.2)
        revived = study.ask()
        assert revived.params == leased.params
        study.tell(revived, value=abs(revived.params["x"]))
        assert cl.study(key)["n_completed"] == 4
        assert fab.respawns + fab.failovers >= 1
    finally:
        fab.stop()


# --------------------------------------------------------------------------- #
# in-process router mode (REPRO_WORKERS / HttpServiceRunner(workers=N))
# --------------------------------------------------------------------------- #
def test_runner_fabric_mode_preserves_semantics():
    storage = InMemoryStorage()
    tokens = TokenManager()
    servers = [HopaasServer(storage=storage, tokens=tokens, seed=i)
               for i in range(2)]
    # pin the evloop backend: the router needs the dispatcher hook, which
    # the threaded frontend (REPRO_FRONTEND=threaded CI pass) lacks
    runner = HttpServiceRunner(servers, backend="evloop",
                               workers=3).start()
    try:
        cl = Client(HttpTransport(runner.host, runner.port),
                    tokens.issue("t"))
        studies = [_study(cl, name=f"inproc-{i}") for i in range(5)]
        for s in studies:
            t = s.ask()
            s.tell(t, value=abs(t.x))
        assert {s["name"] for s in cl.studies()} >= \
            {f"inproc-{i}" for i in range(5)}
        results = cl.tell_batch(
            [{"trial_uid": s.ask().uid, "value": 0.1, "state": "completed"}
             for s in studies])
        assert all(r["status"] == 200 for r in results)
        stats = runner.frontend_stats()
        assert stats["fabric_workers"] == 3
        assert stats["dispatcher"]["proxied"] > 0
        # the shared storage saw every write exactly once
        assert all(len(list(cl.iter_trials(s.study_key,
                                           state="completed"))) == 2
                   for s in studies)
    finally:
        runner.stop()


def test_runner_threaded_backend_ignores_workers():
    storage = InMemoryStorage()
    tokens = TokenManager()
    runner = HttpServiceRunner(
        [HopaasServer(storage=storage, tokens=tokens)],
        backend="threaded", workers=4)
    assert runner.fabric_workers == 1
    runner.start()
    try:
        cl = Client(HttpTransport(runner.host, runner.port),
                    tokens.issue("t"))
        s = _study(cl, name="threaded")
        t = s.ask()
        s.tell(t, value=0.0)
    finally:
        runner.stop()


def test_fabric_inline_single_worker_matches_plain_service():
    fab = ShardFabric(workers=1, storage="memory").start()
    try:
        assert fab.inline
        cl, _tok = _client(fab)
        s = _study(cl, name="solo")
        t = s.ask()
        s.tell(t, value=abs(t.x))
        assert cl.study(s.study_key)["n_completed"] == 1
        assert fab.stats()["workers"] == 1
        assert "dispatcher" not in fab.stats()
    finally:
        fab.stop()
