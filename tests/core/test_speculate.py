"""Speculative ask pipeline — constant-liar pending view + off-lock queue.

The pending-aware ``ObservationCache`` must keep its liar rows exactly
in step with the trial lifecycle (lease -> replace-on-tell ->
vanish-on-requeue), reproduce bit-identical augmented buffers across a
WAL replay, and the speculative queue must never move study state
off-WAL: the ``state_digest`` after a crash mid-speculation matches a
clean recovery (the queue is a cache; it restarts empty).
"""
import time

import numpy as np
import pytest

from repro.core.auth import TokenManager
from repro.core.durable import DurableStorage
from repro.core.obs_cache import (LIAR_MODES, ObservationCache, check_liar,
                                  liar_value)
from repro.core.server import HopaasServer
from repro.core.space import SearchSpace
from repro.core.speculate import SpeculativeQueue
from repro.core.types import Direction

PROPS = {"x": {"type": "uniform", "low": -5, "high": 5},
         "lr": {"type": "loguniform", "low": 1e-5, "high": 1e-1},
         "c": {"type": "categorical", "choices": ["a", "b", "c"]}}

SPEC = {"name": "spec-study", "properties": PROPS,
        "sampler": {"name": "tpe", "n_startup_trials": 4, "liar": "mean"}}


def _server(**kw):
    return HopaasServer(tokens=TokenManager(), seed=11, **kw)


def _fill(server, key, n, worker="w0"):
    """n completed trials through the public ask/tell path."""
    rng = np.random.default_rng(3)
    for _ in range(n):
        (t,) = server.op_ask(key, worker, 1)
        server.op_tell(t["uid"], float(rng.uniform(0, 10)), "completed")


# --------------------------------------------------------------------- #
# liar imputation values
# --------------------------------------------------------------------- #
def test_liar_value_modes():
    y = np.array([3.0, 1.0, 2.0])
    assert liar_value(y, "min") == 1.0
    assert liar_value(y, "max") == 3.0
    # mean is computed as sum/len over the id-ordered vector — the exact
    # expression the cache uses, so replay equality is bit-exact
    assert liar_value(y, "mean") == float(np.sum(y) / len(y))
    for mode in LIAR_MODES:
        assert check_liar(mode) == mode
    with pytest.raises(ValueError):
        check_liar("median")


# --------------------------------------------------------------------- #
# pending-view lifecycle
# --------------------------------------------------------------------- #
def test_pending_row_appears_on_lease_and_is_replaced_on_tell():
    server = _server()
    _, study = server.op_create_study(SPEC)
    key = study["key"]
    _fill(server, key, 6)
    ctx = server._context_for_key(key)
    cache = ctx.cache.sync(server.storage, key)
    X0, y0 = cache.observations()
    assert cache.pending_count == 0

    (t,) = server.op_ask(key, "w1", 1)
    cache.sync(server.storage, key)
    assert cache.pending_count == 1
    Xa, ya = cache.augmented()
    assert Xa.shape[0] == len(y0) + 1
    lv = liar_value(y0, "mean")
    assert ya[-1] == lv and cache.liar_value() == lv
    # observed rows are untouched by the fantasy row
    assert np.array_equal(Xa[:len(y0)], X0)
    assert np.array_equal(ya[:len(y0)], y0)

    server.op_tell(t["uid"], 4.25, "completed")
    cache.sync(server.storage, key)
    assert cache.pending_count == 0
    Xb, yb = cache.augmented()
    # replaced, not duplicated: same row count, real value present
    assert Xb.shape[0] == len(y0) + 1
    assert 4.25 in yb
    server.close()


def test_pending_row_vanishes_on_fail_and_on_lease_expiry():
    server = _server(lease_seconds=0.05)
    _, study = server.op_create_study(SPEC)
    key = study["key"]
    _fill(server, key, 5)
    ctx = server._context_for_key(key)

    (t,) = server.op_ask(key, "w1", 1)
    cache = ctx.cache.sync(server.storage, key)
    assert cache.pending_count == 1
    server.op_tell(t["uid"], None, "failed")
    cache.sync(server.storage, key)
    assert cache.pending_count == 0

    (t2,) = server.op_ask(key, "w2", 1)
    cache.sync(server.storage, key)
    assert cache.pending_count == 1
    time.sleep(0.08)
    with ctx.lock:
        server._sweep_study(key, time.time())     # expired -> requeued
    cache.sync(server.storage, key)
    assert cache.pending_count == 0
    assert cache.count == 5                       # nothing fake completed
    server.close()


def test_pending_fingerprint_tracks_set_not_syncs():
    server = _server()
    _, study = server.op_create_study(SPEC)
    key = study["key"]
    _fill(server, key, 4)
    ctx = server._context_for_key(key)
    cache = ctx.cache.sync(server.storage, key)
    tok = cache.token
    cache.sync(server.storage, key)               # no-op sync
    assert cache.token == tok                     # memo keys stay valid
    server.op_ask(key, "w1", 1)
    cache.sync(server.storage, key)
    assert cache.token != tok
    server.close()


def test_wal_replay_reproduces_bit_identical_augmented_buffers(tmp_path):
    root = str(tmp_path / "wal")
    storage = DurableStorage(root, fsync="always", auto_compact=False)
    server = _server(storage=storage)
    _, study = server.op_create_study(SPEC)
    key = study["key"]
    _fill(server, key, 7)
    server.op_ask(key, "w1", 2)                   # leave 2 RUNNING
    space = SearchSpace.from_properties(PROPS)

    live = ObservationCache(space, Direction.MINIMIZE, liar="mean")
    live.sync(storage, key)
    Xl, yl = live.augmented()
    Pl = live.padded_augmented()
    storage.close()                               # crash-equivalent: WAL is
                                                  # fsynced, no snapshot step

    replayed = DurableStorage(root, fsync="off")
    again = ObservationCache(space, Direction.MINIMIZE, liar="mean")
    again.sync(replayed, key)
    Xr, yr = again.augmented()
    Pr = again.padded_augmented()
    assert again.pending_count == 2
    assert np.array_equal(Xl, Xr) and np.array_equal(yl, yr)
    for a, b in zip(Pl, Pr):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    replayed.close()


# --------------------------------------------------------------------- #
# queue semantics
# --------------------------------------------------------------------- #
def test_queue_staleness_policy_and_cas():
    q = SpeculativeQueue()
    assert q.take(0, 8) is None and q.stats()["misses"] == 1

    assert q.publish(10, [{"x": 1.0}, {"x": 2.0}])
    assert q.take(10, 8) == {"x": 2.0}            # exact version: hit
    assert q.take(14, 8) == {"x": 1.0}            # within bound: stale hit
    assert q.publish(20, [{"x": 3.0}])
    assert q.take(40, 8) is None                  # age 20 > 8: discarded
    s = q.stats()
    assert (s["hits"], s["stale_hits"], s["misses"]) == (1, 1, 2)
    assert s["discarded"] == 1 and s["queued"] == 0

    # CAS: an older compute can never land above a newer buffer
    assert q.publish(50, [{"x": 4.0}])
    assert not q.publish(30, [{"x": 5.0}])
    assert q.stats()["rejected"] == 1
    assert q.take(50, 0) == {"x": 4.0}


def test_queue_retains_previous_round_leftovers():
    q = SpeculativeQueue()
    q.publish(10, [{"x": 1.0}, {"x": 2.0}])
    q.publish(12, [{"x": 3.0}])               # leftovers of v10 survive
    assert q.depth() == 3
    assert q.take(12, 64) == {"x": 3.0}       # newest-first
    assert q.take(12, 64) == {"x": 2.0}       # then the older buffer
    q.publish(12, [{"x": 4.0}])
    q.publish(12, [{"x": 5.0}])               # same-version merge
    assert q.depth() == 3
    s = q.stats()
    assert s["published"] == 4 and s["queued"] == 3


# --------------------------------------------------------------------- #
# end-to-end pipeline
# --------------------------------------------------------------------- #
def _wait_for(pred, timeout=5.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.01)
    return pred()


def test_speculative_pipeline_precomputes_and_drains():
    server = _server(speculate_depth=6)
    try:
        _, study = server.op_create_study(SPEC)
        key = study["key"]
        _fill(server, key, 8)                     # past n_startup -> ready
        ctx = server._context_for_key(key)
        assert ctx.spec is not None
        assert _wait_for(lambda: ctx.spec.depth() > 0)

        trials = server.op_ask(key, "w0", 3, parallelism=12)
        assert len(trials) == 3
        stats = server.speculation_stats()
        assert stats["enabled"] and stats["published"] >= 1
        assert stats["hits"] + stats["stale_hits"] >= 1
        assert ctx.parallelism == 12              # hint raises the depth
        # the wire surfaces carry the same counters
        assert server.op_health()["speculation"]["enabled"]
        assert "speculation" in server.op_version_v2()["storage"]
    finally:
        server.close()


def test_miss_path_overprovisions_the_queue():
    """An inline miss widens its fused draw and publishes the surplus —
    the queue refills from the demand side even with the background
    worker stopped (it is GIL-starved under a real contended fleet)."""
    server = _server(speculate_depth=6)
    try:
        _, study = server.op_create_study(SPEC)
        key = study["key"]
        _fill(server, key, 8)                     # past n_startup -> ready
        ctx = server._context_for_key(key)
        server._speculator.stop()                 # only the miss path left
        with ctx.lock:
            ctx.spec._bufs.clear()                # force the next ask to miss
        before = ctx.spec.stats()
        (t,) = server.op_ask(key, "w0", 1)
        after = ctx.spec.stats()
        assert after["misses"] == before["misses"] + 1
        assert after["queued"] >= 4               # surplus landed
        # and the very next ask drains one of them without missing
        (t2,) = server.op_ask(key, "w1", 1)
        final = ctx.spec.stats()
        assert final["misses"] == after["misses"]
        assert final["hits"] + final["stale_hits"] > \
            after["hits"] + after["stale_hits"]
        assert t2["params"] != t["params"]
    finally:
        server.close()


def test_parallelism_hint_accepted_over_the_wire():
    server = _server(speculate_depth=4)
    try:
        _, study = server.op_create_study(SPEC)
        key = study["key"]
        tok = server.tokens.issue("t")
        status, payload, _ = server.handle_request(
            "POST", f"/api/v2/studies/{key}/trials:ask",
            {"worker_id": "w", "parallelism": 32},
            {"authorization": f"Bearer {tok}"})
        assert status == 200, payload
        assert server._context_for_key(key).parallelism == 32
        status, payload, _ = server.handle_request(
            "POST", f"/api/v2/studies/{key}/trials:ask",
            {"worker_id": "w", "parallelism": 0},
            {"authorization": f"Bearer {tok}"})
        assert status == 422                      # min_value=1 enforced
    finally:
        server.close()


def test_batched_ask_is_not_k_copies():
    server = _server()
    try:
        _, study = server.op_create_study(SPEC)
        key = study["key"]
        _fill(server, key, 10)
        trials = server.op_ask(key, "w0", 8)
        pts = {tuple(sorted(t["params"].items())) for t in trials}
        assert len(pts) == 8, "constant-liar batch collapsed to duplicates"
    finally:
        server.close()


def test_speculation_never_moves_state_off_wal(tmp_path):
    """state_digest across a crash mid-speculation == clean recovery."""
    root = str(tmp_path / "wal")
    storage = DurableStorage(root, fsync="always", auto_compact=False)
    server = _server(storage=storage, speculate_depth=6)
    try:
        _, study = server.op_create_study(SPEC)
        key = study["key"]
        _fill(server, key, 8)
        ctx = server._context_for_key(key)
        assert _wait_for(lambda: ctx.spec.depth() > 0)
        server.op_ask(key, "w0", 2)               # drain mid-flight
        assert _wait_for(lambda: ctx.spec.depth() > 0)  # refilled
        digest = storage.state_digest()
    finally:
        server.close()                            # stops the precompute
    storage.close()

    replayed = DurableStorage(root, fsync="off")
    try:
        assert replayed.state_digest() == digest
    finally:
        replayed.close()


def test_fabric_workers_inherit_depth_and_fleet_health_aggregates(
        monkeypatch):
    """REPRO_SPECULATE propagates to fabric worker processes; the fleet
    health rolls their per-worker counters into one block."""
    from repro.core.fabric import ShardFabric
    monkeypatch.setenv("REPRO_SPECULATE", "4")
    fab = ShardFabric(workers=2, storage="memory").start()
    try:
        spec = fab.health()["speculation"]
        assert spec["enabled"] is True
        assert spec["workers_reporting"] == 2
    finally:
        fab.stop()


def test_speculation_off_by_default_and_proposals_deterministic():
    a, b = _server(), _server()
    try:
        assert a._speculator is None              # REPRO_SPECULATE unset
        for srv in (a, b):
            _, study = srv.op_create_study(SPEC)
        key = study["key"]
        seqs = []
        for srv in (a, b):
            rng = np.random.default_rng(5)
            out = []
            for _ in range(6):
                (t,) = srv.op_ask(key, "w", 1)
                srv.op_tell(t["uid"], float(rng.uniform(0, 10)), "completed")
                out.append(tuple(sorted(t["params"].items())))
            seqs.append(out)
        assert seqs[0] == seqs[1]
    finally:
        a.close()
        b.close()
