"""Pruner semantics: early termination of non-promising trials (sec. 2)."""
import numpy as np
import pytest

from repro.core.pruners import make_pruner
from repro.core.types import (Direction, Study, StudyConfig, Trial, TrialState)


def study_with_history(curves, direction=Direction.MINIMIZE, states=None):
    """curves: list of per-trial loss curves already 'reported'."""
    cfg = StudyConfig(name="p", properties={}, direction=direction)
    trials = []
    for i, curve in enumerate(curves):
        t = Trial(trial_id=i, uid=f"p:{i}", study_key="p", params={},
                  state=(states[i] if states else TrialState.COMPLETED),
                  value=curve[-1],
                  intermediates={s: v for s, v in enumerate(curve)})
        trials.append(t)
    return Study(config=cfg, trials=trials)


def running_trial(curve, tid=99):
    return Trial(trial_id=tid, uid=f"p:{tid}", study_key="p", params={},
                 state=TrialState.RUNNING,
                 intermediates={s: v for s, v in enumerate(curve)})


def test_median_prunes_bad_trial():
    good = [[10 - s for s in range(10)] for _ in range(5)]     # reach ~1
    study = study_with_history(good)
    bad = running_trial([100.0, 99.0, 98.0])
    study.trials.append(bad)
    pruner = make_pruner({"name": "median", "n_startup_trials": 3})
    assert pruner.should_prune(study, bad, 2)


def test_median_keeps_good_trial():
    good = [[10 - s for s in range(10)] for _ in range(5)]
    study = study_with_history(good)
    better = running_trial([8.0, 6.5, 5.0])
    study.trials.append(better)
    pruner = make_pruner({"name": "median", "n_startup_trials": 3})
    assert not pruner.should_prune(study, better, 2)


def test_median_respects_startup_and_warmup():
    study = study_with_history([[1.0, 1.0]])
    bad = running_trial([100.0, 100.0])
    study.trials.append(bad)
    pruner = make_pruner({"name": "median", "n_startup_trials": 4})
    assert not pruner.should_prune(study, bad, 1)     # not enough history
    pruner2 = make_pruner({"name": "median", "n_startup_trials": 0,
                           "n_warmup_steps": 5})
    assert not pruner2.should_prune(study, bad, 1)    # still warming up


def test_median_maximize_direction():
    good = [[s * 1.0 for s in range(10)] for _ in range(5)]    # rising = good
    study = study_with_history(good, direction=Direction.MAXIMIZE)
    bad = running_trial([0.0, 0.0, 0.0])
    study.trials.append(bad)
    pruner = make_pruner({"name": "median", "n_startup_trials": 3})
    assert pruner.should_prune(study, bad, 2)


def test_percentile_is_laxer_than_median():
    curves = [[float(v)] * 3 for v in (1, 2, 3, 4, 5, 6, 7, 8, 9)]
    study = study_with_history(curves)
    mid = running_trial([5.5, 5.5, 5.5])
    study.trials.append(mid)
    assert make_pruner({"name": "median", "n_startup_trials": 3}
                       ).should_prune(study, mid, 2)
    assert not make_pruner({"name": "percentile", "percentile": 90.0,
                            "n_startup_trials": 3}).should_prune(study, mid, 2)


def test_sha_rungs():
    pruner = make_pruner({"name": "sha", "min_resource": 2, "reduction_factor": 3})
    assert pruner.rung_of(0) is None
    assert pruner.rung_of(1) == 0           # resource 2
    assert pruner.rung_of(5) == 1           # resource 6
    assert pruner.rung_resource(0) == 2 and pruner.rung_resource(1) == 6


def test_sha_prunes_bottom_of_rung():
    curves = [[float(v)] * 4 for v in (1, 2, 3, 4, 5, 6, 7, 8)]
    study = study_with_history(curves)
    worst = running_trial([9.0, 9.0])
    study.trials.append(worst)
    pruner = make_pruner({"name": "sha", "min_resource": 2, "reduction_factor": 3})
    assert pruner.should_prune(study, worst, 1)
    best = running_trial([0.5, 0.5], tid=98)
    study.trials.append(best)
    assert not pruner.should_prune(study, best, 1)


def test_hyperband_brackets_deterministic():
    pruner = make_pruner({"name": "hyperband", "min_resource": 1,
                          "max_resource": 27, "reduction_factor": 3})
    assert len(pruner.brackets) == 4
    t = running_trial([1.0])
    assert pruner.bracket_of(t) is pruner.bracket_of(t)


def test_patient_prunes_plateau():
    study = study_with_history([[1.0]])
    plateau = running_trial([5.0, 4.0] + [4.0] * 10)
    study.trials.append(plateau)
    pruner = make_pruner({"name": "patient", "patience": 4})
    assert pruner.should_prune(study, plateau, 11)
    improving = running_trial([5.0 - 0.3 * s for s in range(12)], tid=98)
    study.trials.append(improving)
    assert not pruner.should_prune(study, improving, 11)


def test_none_pruner_never_prunes():
    study = study_with_history([[0.0] * 5] * 10)
    bad = running_trial([1e9] * 5)
    study.trials.append(bad)
    assert not make_pruner({"name": "none"}).should_prune(study, bad, 4)


def test_unknown_specs_raise():
    with pytest.raises(ValueError):
        make_pruner({"name": "nope"})
    from repro.core.samplers import make_sampler
    with pytest.raises(ValueError):
        make_sampler({"name": "nope"})


def test_pruning_saves_compute_end_to_end():
    """Integration: a median-pruned campaign spends fewer total steps than
    an unpruned one while finding the same optimum region."""
    from repro.core import (Client, ClientStudy, DirectTransport, HopaasServer,
                            suggestions)

    def run(pruner):
        srv = HopaasServer(seed=1)
        cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
        study = ClientStudy(name="c", client=cl,
                            properties={"x": suggestions.uniform(0, 4)},
                            sampler={"name": "random"}, pruner=pruner)
        total_steps = 0
        for _ in range(24):
            with study.trial() as tr:
                # loss curve converges to x^2: bad x is visible early
                target = tr.x ** 2
                for step in range(16):
                    total_steps += 1
                    val = target + (16 - step) * 0.05
                    if tr.should_prune(step, val):
                        break
                tr.loss = target
        (s,) = [x for x in cl.studies() if x["name"] == "c"]
        return total_steps, s["best_value"], s["n_pruned"]

    steps_none, best_none, _ = run({"name": "none"})
    steps_med, best_med, pruned = run({"name": "median", "n_startup_trials": 4})
    assert pruned > 0
    assert steps_med < steps_none * 0.9
    assert best_med < 1.0 and best_none < 1.0
