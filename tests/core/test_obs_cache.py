"""Cache-coherence and codec-equivalence regression tests.

The incremental ``ObservationCache`` must stay *bit-identical* to the
from-scratch ``Sampler.observations`` scan through every mutation the
service can apply (tell / prune / fail / lease-expiry requeue) and across
journal replay, and the vectorized space codec must agree with the scalar
per-kind reference — otherwise cached and uncached asks would propose
different points.
"""
import os
import time

import numpy as np
import pytest

from repro.core.obs_cache import ObservationCache
from repro.core.samplers import make_sampler
from repro.core.samplers.base import Sampler
from repro.core.server import HopaasServer
from repro.core.space import Param, SearchSpace
from repro.core.storage import InMemoryStorage, JournalStorage
from repro.core.types import Direction, StudyConfig, TrialState

PROPS = {"x": {"type": "uniform", "low": -5, "high": 5},
         "lr": {"type": "loguniform", "low": 1e-5, "high": 1e-1},
         "n": {"type": "int", "low": 2, "high": 9},
         "c": {"type": "categorical", "choices": ["a", "b", "c"]}}


def _scratch(ctx, storage):
    study = storage.get_study(ctx.key)
    return Sampler.observations(ctx.space, study.trials, ctx.config.direction)


def _assert_coherent(ctx, storage):
    ctx.cache.sync(storage, ctx.key)
    Xc, yc = ctx.cache.observations()
    Xs, ys = _scratch(ctx, storage)
    assert Xc.shape == Xs.shape and yc.shape == ys.shape
    assert np.array_equal(Xc, Xs), "cache X diverged from scratch scan"
    assert np.array_equal(yc, ys), "cache y diverged from scratch scan"


def _drive_sequence(server, body):
    """Mixed tell/prune/fail/requeue traffic; checks coherence throughout."""
    ident = {"user": "t"}
    rng = np.random.default_rng(7)
    pruned_at = {3, 11}
    failed_at = {5, 13}
    for i in range(24):
        status, payload = server._ask(dict(body), ident)
        assert status == 200
        uid = payload["trial_uid"]
        ctx = server._context_for_key(payload["study_key"])
        _assert_coherent(ctx, server.storage)
        if i in pruned_at:       # server-side prune via heartbeat
            server._should_prune({"trial_uid": uid, "step": 0, "value": 1e9})
            server._tell({"trial_uid": uid, "value": float(rng.uniform()),
                          "state": "pruned"})
        elif i in failed_at:     # worker died after reporting
            server._tell({"trial_uid": uid, "value": None, "state": "failed"})
        else:
            server._tell({"trial_uid": uid,
                          "value": float(rng.uniform(-10, 10)),
                          "state": "completed"})
        _assert_coherent(ctx, server.storage)
    return ctx


def test_cache_matches_scratch_through_mixed_traffic():
    server = HopaasServer(seed=0)
    body = {"name": "coherence", "properties": PROPS,
            "sampler": {"name": "tpe", "n_startup_trials": 4}}
    ctx = _drive_sequence(server, body)
    Xc, yc = ctx.cache.observations()
    assert len(yc) == 24 - 2 - 2      # minus 2 failed, minus 2 pruned
    # pruned trials must not be observations
    study = server.storage.get_study(ctx.key)
    n_completed = sum(t.state == TrialState.COMPLETED for t in study.trials)
    assert len(yc) == n_completed


def test_cache_coherent_across_requeue():
    server = HopaasServer(seed=1, lease_seconds=0.01)
    body = {"name": "requeue", "properties": PROPS,
            "sampler": {"name": "tpe", "n_startup_trials": 2}}
    ident = {"user": "t"}
    _, p1 = server._ask(dict(body), ident)
    ctx = server._context_for_key(p1["study_key"])
    time.sleep(0.03)                   # lease lapses -> FAILED + requeue
    _, p2 = server._ask(dict(body), ident)
    assert p2["properties"] == p1["properties"]   # requeued params
    _assert_coherent(ctx, server.storage)
    server._tell({"trial_uid": p2["trial_uid"], "value": 1.0,
                  "state": "completed"})
    _assert_coherent(ctx, server.storage)


def test_cache_coherent_after_journal_replay(tmp_path):
    path = os.path.join(tmp_path, "journal.jsonl")
    server = HopaasServer(storage=JournalStorage(path), seed=3)
    body = {"name": "replay", "properties": PROPS,
            "sampler": {"name": "tpe", "n_startup_trials": 4}}
    ctx = _drive_sequence(server, body)
    before = ctx.cache.observations()
    server.storage.close()

    restarted = HopaasServer(storage=JournalStorage(path), seed=3)
    ctx2 = restarted._context_for_key(ctx.key)
    assert ctx2 is not None
    _assert_coherent(ctx2, restarted.storage)
    after = ctx2.cache.observations()
    assert np.array_equal(before[0], after[0])
    assert np.array_equal(before[1], after[1])
    restarted.storage.close()


@pytest.mark.parametrize("name", ["tpe", "gp", "cmaes"])
def test_cached_and_uncached_proposals_identical(name):
    """The cache must not change what the sampler proposes — same rng,
    same history, with/without cache => byte-identical params."""
    space = SearchSpace.from_properties(PROPS)
    cfg = StudyConfig(name="ident", properties=PROPS)
    storage = InMemoryStorage()
    study, _ = storage.get_or_create_study(cfg)
    rng = np.random.default_rng(5)
    for i in range(20):
        t = storage.add_trial(study.key, space.sample_uniform(rng), None, None)
        storage.update_trial(t.uid, value=float(rng.uniform(-3, 3)),
                             state=TrialState.COMPLETED, lease_deadline=None)
    cache = ObservationCache(space, cfg.direction)
    cache.sync(storage, study.key)

    s1 = make_sampler({"name": name})
    s2 = make_sampler({"name": name})
    r1 = np.random.default_rng(42)
    r2 = np.random.default_rng(42)
    p_cached = s1.suggest(space, study.trials, cfg.direction, r1, cache=cache)
    p_scratch = s2.suggest(space, study.trials, cfg.direction, r2)
    assert p_cached == p_scratch


def test_cache_padded_pow2_signature_stability():
    space = SearchSpace.from_properties(PROPS)
    cfg = StudyConfig(name="pad", properties=PROPS)
    storage = InMemoryStorage()
    study, _ = storage.get_or_create_study(cfg)
    cache = ObservationCache(space, cfg.direction)
    rng = np.random.default_rng(0)
    shapes = set()
    for i in range(40):
        t = storage.add_trial(study.key, space.sample_uniform(rng), None, None)
        storage.update_trial(t.uid, value=float(i), state=TrialState.COMPLETED,
                             lease_deadline=None)
        cache.sync(storage, study.key)
        X, y, mask = cache.padded()
        assert X.shape[0] == y.shape[0] == mask.shape[0]
        assert X.shape[0] & (X.shape[0] - 1) == 0       # power of two
        assert int(mask.sum()) == i + 1
        shapes.add(X.shape)
    # 40 observations -> only the pow-2 ladder of shapes, not 40 distinct
    assert len(shapes) <= 4


def test_incremental_best_matches_scan():
    for direction in (Direction.MINIMIZE, Direction.MAXIMIZE):
        cfg = StudyConfig(name=f"best-{direction.value}", properties=PROPS,
                          direction=direction)
        storage = InMemoryStorage()
        study, _ = storage.get_or_create_study(cfg)
        space = SearchSpace.from_properties(PROPS)
        rng = np.random.default_rng(11)
        for i in range(30):
            t = storage.add_trial(study.key, space.sample_uniform(rng),
                                  None, None)
            if i % 5 == 3:
                storage.update_trial(t.uid, state=TrialState.FAILED,
                                     lease_deadline=None)
                continue
            storage.update_trial(t.uid, value=float(rng.uniform(-9, 9)),
                                 state=TrialState.COMPLETED,
                                 lease_deadline=None)
            fast = storage.best_trial(study.key)
            slow = study.best_trial()
            assert fast is not None and slow is not None
            assert fast.value == slow.value


# ---------------------------------------------------------------------- #
# vectorized codec vs the scalar per-kind reference
# ---------------------------------------------------------------------- #
KIND_PARAMS = [
    Param(name="u", kind="uniform", low=-5.0, high=5.0),
    Param(name="lg", kind="loguniform", low=1e-6, high=1e2),
    Param(name="i", kind="int", low=-3, high=12),
    Param(name="li", kind="logint", low=1, high=4096),
    Param(name="c", kind="categorical", choices=("a", "b", "c", "d", "e")),
]


@pytest.mark.parametrize("param", KIND_PARAMS, ids=lambda p: p.kind)
def test_vector_codec_matches_scalar_per_kind(param):
    space = SearchSpace([param])
    us = np.linspace(0.0, 1.0, 257)[:, None]
    decoded = space.from_unit_matrix(us)
    for row, u in zip(decoded, us[:, 0]):
        ref = param.from_unit(u)
        if isinstance(ref, float):
            assert row[param.name] == pytest.approx(ref, rel=1e-12)
        else:
            assert row[param.name] == ref
    encoded = space.to_unit_matrix(decoded)
    for enc, row in zip(encoded[:, 0], decoded):
        assert enc == pytest.approx(param.to_unit(row[param.name]),
                                    rel=1e-9, abs=1e-12)


def test_all_constant_space_decodes():
    """dim-0 spaces (every property pinned to a constant) must decode to
    the constants dict, not crash the vectorized codec."""
    space = SearchSpace.from_properties({"lr": 0.1, "opt": "adam"})
    assert space.dim == 0
    rng = np.random.default_rng(0)
    assert space.sample_uniform(rng) == {"lr": 0.1, "opt": "adam"}
    assert space.from_unit_vector(np.zeros(0)) == {"lr": 0.1, "opt": "adam"}
    assert space.grid() == [{"lr": 0.1, "opt": "adam"}]
    for name in ("random", "tpe", "gp", "cmaes", "halton"):
        s = make_sampler({"name": name})
        assert s.suggest(space, [], Direction.MINIMIZE, rng) == \
            {"lr": 0.1, "opt": "adam"}


def test_categorical_equal_width_bins():
    """Uniform candidates must weight every choice equally (the old
    round(u*(n-1)) binning gave edge choices half-width bins)."""
    p = Param(name="c", kind="categorical", choices=("a", "b", "c", "d"))
    us = np.linspace(0.0, 1.0, 4000, endpoint=False)
    space = SearchSpace([p])
    rows = space.from_unit_matrix(us[:, None])
    counts = {ch: 0 for ch in p.choices}
    for r in rows:
        counts[r["c"]] += 1
    assert max(counts.values()) == min(counts.values())
    for ch in p.choices:            # inverse maps back into the same bin
        assert p.from_unit(p.to_unit(ch)) == ch
        assert space.from_unit_matrix(
            np.array([[p.to_unit(ch)]]))[0]["c"] == ch


# ---------------------------------------------------------------------- #
# incremental pruner indices vs a reference scan
# ---------------------------------------------------------------------- #
def test_step_report_index_matches_scan():
    server = HopaasServer(seed=2)
    body = {"name": "reports", "properties": {"x": PROPS["x"]},
            "sampler": {"name": "random"}, "pruner": {"name": "median"}}
    ident = {"user": "t"}
    rng = np.random.default_rng(3)
    uids = []
    for i in range(8):
        _, p = server._ask(dict(body), ident)
        uids.append(p["trial_uid"])
        for step in range(1 + int(rng.integers(0, 4))):
            server._should_prune({"trial_uid": p["trial_uid"], "step": step,
                                  "value": float(rng.uniform())})
        server._tell({"trial_uid": p["trial_uid"],
                      "value": float(rng.uniform()), "state": "completed"})
    ctx = server._context_for_key(p["study_key"])
    study = server.storage.get_study(ctx.key)
    for step in range(4):
        ref = {t.uid: t.intermediates[step] for t in study.trials
               if step in t.intermediates}
        assert study.reports_at(step) == ref


def test_unmanaged_study_sees_inplace_report_mutation():
    """Hand-built studies (direct Pruner API use) must keep live-scan
    semantics: mutating trial.intermediates in place is always observed."""
    from repro.core.types import Study, Trial

    cfg = StudyConfig(name="um", properties={})
    trials = [Trial(trial_id=i, uid=f"um:{i}", study_key="um", params={},
                    state=TrialState.RUNNING, intermediates={0: float(i)})
              for i in range(3)]
    study = Study(config=cfg, trials=trials)
    assert study.reports_at(0) == {"um:0": 0.0, "um:1": 1.0, "um:2": 2.0}
    trials[1].intermediates[1] = 7.0          # in-place, no append
    assert study.reports_at(1) == {"um:1": 7.0}


def test_rung_cache_consistent_on_step_rereport():
    """Re-reporting a step (client retry) replaces its value; the rung
    snapshot must agree with a from-scratch rebuild, not keep the min of
    old and new."""
    server = HopaasServer(seed=4)
    body = {"name": "rereport", "properties": {"x": PROPS["x"]},
            "sampler": {"name": "random"},
            "pruner": {"name": "sha", "min_resource": 1}}
    ident = {"user": "t"}
    _, p = server._ask(dict(body), ident)
    server._should_prune({"trial_uid": p["trial_uid"], "step": 0, "value": 1.0})
    ctx = server._context_for_key(p["study_key"])
    study = server.storage.get_study(ctx.key)
    assert study.rung_value(p["trial_uid"], 1, 1.0) == 1.0
    server._should_prune({"trial_uid": p["trial_uid"], "step": 0, "value": 9.0})
    incremental = study.rung_value(p["trial_uid"], 1, 1.0)
    study._step_reports = None                 # force full rebuild
    rebuilt = study.rung_value(p["trial_uid"], 1, 1.0)
    assert incremental == rebuilt == 9.0


def test_incumbent_tie_breaks_by_trial_id():
    """Equal values: storage.best_trial must name the lowest trial_id,
    exactly like the Study.best_trial() scan, regardless of completion
    order."""
    cfg = StudyConfig(name="tie", properties=PROPS)
    storage = InMemoryStorage()
    study, _ = storage.get_or_create_study(cfg)
    t0 = storage.add_trial(study.key, {"x": 0.0}, None, None)
    t1 = storage.add_trial(study.key, {"x": 1.0}, None, None)
    storage.update_trial(t1.uid, value=0.5, state=TrialState.COMPLETED,
                         lease_deadline=None)   # trial 1 completes first
    storage.update_trial(t0.uid, value=0.5, state=TrialState.COMPLETED,
                         lease_deadline=None)
    assert storage.best_trial(study.key).trial_id == \
        study.best_trial().trial_id == 0


def test_should_prune_unresolvable_study_is_404():
    """A trial whose study context cannot be resolved must yield a clean
    404, not a 500 from dereferencing a None context."""
    class AmnesiacStorage(InMemoryStorage):
        def get_study(self, key):
            return None             # simulates a partially replayed store

    storage = AmnesiacStorage()
    server = HopaasServer(storage=storage)
    cfg = StudyConfig(name="ghost", properties={"x": PROPS["x"]})
    study, _ = InMemoryStorage.get_or_create_study(storage, cfg)
    trial = storage.add_trial(study.key, {"x": 0.0}, None, None)
    server._contexts.clear()        # force the _context_for_key lookup
    status, payload = server.handle(
        "POST", f"/api/should_prune/{server.tokens.issue('t')}",
        {"trial_uid": trial.uid, "step": 0, "value": 1.0})
    assert status == 404
    assert "not resolvable" in payload["detail"]
