"""Acquisition-kernel equivalence: the Pallas kernels (interpret mode on
CPU) and the matmul-form jnp fallbacks must both match the naive rank-3
reference formulations the seed code used."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels import backend, matern52_cross, parzen_log_density

TOL = dict(rtol=2e-4, atol=2e-4)


def _naive_parzen(x, obs, mask, bw):
    """The seed formulation: materializes (C, N, D)."""
    z = (x[:, None, :] - obs[None, :, :]) / bw
    logk = (-0.5 * z * z - jnp.log(bw * math.sqrt(2 * math.pi))).sum(-1)
    logk = jnp.where(mask[None, :] > 0, logk, -jnp.inf)
    return jax.scipy.special.logsumexp(logk, axis=1)


def _naive_matern(a, b, ls):
    d = jnp.sqrt(jnp.maximum(
        ((a[:, None, :] - b[None, :, :]) ** 2 / ls ** 2).sum(-1), 1e-12))
    s5d = math.sqrt(5.0) * d
    return (1.0 + s5d + s5d ** 2 / 3.0) * jnp.exp(-s5d)


def _case(c, n, d, n_valid, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(size=(c, d)), jnp.float32)
    obs = jnp.asarray(rng.uniform(size=(n, d)), jnp.float32)
    mask = jnp.asarray((np.arange(n) < n_valid).astype(np.float32))
    bw = jnp.asarray(rng.uniform(0.05, 0.7, size=d), jnp.float32)
    return x, obs, mask, bw


@pytest.mark.parametrize("backend_name", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("c,n,d,n_valid", [
    (64, 8, 1, 3),          # minimum pads
    (64, 32, 5, 20),        # partial mask
    (128, 256, 3, 256),     # full mask, multiple obs tiles
    (256, 512, 11, 300),    # masked tail tiles
])
def test_parzen_matches_naive(backend_name, c, n, d, n_valid):
    x, obs, mask, bw = _case(c, n, d, n_valid)
    ref = _naive_parzen(x, obs, mask, bw)
    out = parzen_log_density(x, obs, mask, bw, backend=backend_name)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("backend_name", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("a,b,d", [(8, 8, 2), (64, 32, 5), (256, 128, 7)])
def test_matern_matches_naive(backend_name, a, b, d):
    rng = np.random.default_rng(1)
    xa = jnp.asarray(rng.uniform(size=(a, d)), jnp.float32)
    xb = jnp.asarray(rng.uniform(size=(b, d)), jnp.float32)
    ls = jnp.asarray(rng.uniform(0.1, 0.5, size=d), jnp.float32)
    ref = _naive_matern(xa, xb, ls)
    out = matern52_cross(xa, xb, ls, backend=backend_name)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_parzen_jit_composable():
    """The op must be callable from inside jax.jit (the TPE path)."""
    x, obs, mask, bw = _case(64, 16, 3, 10)

    @jax.jit
    def f(x, obs, mask, bw):
        return parzen_log_density(x, obs, mask, bw, backend="jnp")

    np.testing.assert_allclose(np.asarray(f(x, obs, mask, bw)),
                               np.asarray(_naive_parzen(x, obs, mask, bw)),
                               **TOL)


def test_backend_auto_selection_off_tpu():
    if jax.default_backend() != "tpu":
        assert backend() == "jnp"


def test_backend_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_HPO_KERNELS", "pallas_interpret")
    assert backend() == "pallas_interpret"
    monkeypatch.setenv("REPRO_HPO_KERNELS", "bogus")
    with pytest.raises(ValueError):
        backend()
