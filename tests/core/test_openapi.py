"""Route-consistency: the OpenAPI document is generated from the router,
and every registered route must appear in it (and vice versa) — the CI
guard that the spec can never drift from the dispatch table."""
import pytest

from repro.core import HopaasServer


@pytest.fixture()
def server():
    return HopaasServer(seed=0)


@pytest.fixture()
def doc(server):
    status, payload, _ = server.handle_request("GET", "/api/v2/openapi")
    assert status == 200
    return payload


def test_every_route_is_documented_and_vice_versa(server, doc):
    registered = {(r.method, r.template) for r in server.router.routes}
    documented = {(method.upper(), template)
                  for template, ops in doc["paths"].items()
                  for method in ops}
    assert registered == documented
    # both API versions are present
    assert any(t.startswith("/api/v2/") for _, t in documented)
    assert any(not t.startswith("/api/v2/") for _, t in documented)


def test_document_structure(doc):
    assert doc["openapi"].startswith("3.")
    assert doc["info"]["title"]
    assert "bearerAuth" in doc["components"]["securitySchemes"]
    # the error envelope is a first-class component
    assert "ErrorEnvelope" in doc["components"]["schemas"]


def test_operations_reference_registered_schemas(server, doc):
    schemas = doc["components"]["schemas"]
    for template, ops in doc["paths"].items():
        for method, op in ops.items():
            body = op.get("requestBody")
            if body is not None:
                ref = body["content"]["application/json"]["schema"]["$ref"]
                name = ref.rsplit("/", 1)[1]
                assert name in schemas, f"{method} {template} -> {ref}"
            # every operation documents the structured error envelope
            assert "4XX" in op["responses"]


def test_path_params_are_documented(doc):
    op = doc["paths"]["/api/v2/studies/{key}/trials"]["get"]
    names = {p["name"]: p for p in op["parameters"]}
    assert names["key"]["in"] == "path"
    assert names["state"]["in"] == "query"
    assert set(names["state"]["schema"]["enum"]) == {
        "running", "completed", "pruned", "failed"}
    assert names["limit"]["schema"]["maximum"] == 500


def test_bearer_security_marked_on_v2_routes(server, doc):
    for template, ops in doc["paths"].items():
        for method, op in ops.items():
            route = next(r for r in server.router.routes
                         if r.template == template
                         and r.method == method.upper())
            if route.auth == "bearer":
                assert op.get("security") == [{"bearerAuth": []}], template
            else:
                assert "security" not in op, template


def test_create_study_documents_201(doc):
    responses = doc["paths"]["/api/v2/studies"]["post"]["responses"]
    assert set(responses) >= {"200", "201", "4XX"}
    assert responses["201"]["description"] == "created"


def test_document_is_json_serializable(doc):
    import json
    json.dumps(doc)
