"""Client-side transport hardening: the pooled connection transport
(checkout/checkin, concurrent callers on distinct sockets) and the
non-JSON-response guard shared by both HTTP transports."""
import json
import socket
import threading

import pytest

from repro.core import (Client, ClientStudy, HopaasError, HopaasServer,
                        HOPAAS_VERSION, HttpServiceRunner, HttpTransport,
                        InMemoryStorage, PooledHttpTransport, TokenManager,
                        suggestions)


@pytest.fixture()
def service():
    storage, tokens = InMemoryStorage(), TokenManager()
    runner = HttpServiceRunner(
        [HopaasServer(storage=storage, tokens=tokens, seed=0)]).start()
    yield runner, tokens
    runner.stop()


def test_pooled_round_trip(service):
    runner, tokens = service
    tr = PooledHttpTransport(runner.host, runner.port, pool_size=2)
    client = Client(tr, tokens.issue("u"))
    assert client.version() == HOPAAS_VERSION
    study = ClientStudy(name="pool", client=client,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"})
    with study.trial() as t:
        t.loss = (t.x - 0.3) ** 2
    assert client.study(study.study_key)["n_completed"] == 1
    tr.close()


def test_pooled_concurrent_callers_share_one_transport(service):
    """More threads than sockets: checkout blocks instead of corrupting
    a shared connection; every response matches its request."""
    runner, tokens = service
    tok = tokens.issue("u")
    tr = PooledHttpTransport(runner.host, runner.port, pool_size=3)
    shared = Client(tr, tok)
    study = ClientStudy(name="pool-mt", client=shared,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"})
    uids = [t.uid for t in study.ask_batch(10)]
    errors = []

    def worker(uid: str) -> None:
        for _ in range(10):
            got = shared.trial(uid)
            if got["uid"] != uid:
                errors.append((uid, got["uid"]))

    threads = [threading.Thread(target=worker, args=(u,)) for u in uids]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    tr.close()


def test_pooled_from_url_and_validation(service):
    runner, tokens = service
    tr = PooledHttpTransport.from_url(runner.url, pool_size=1)
    assert (tr.host, tr.port) == (runner.host, runner.port)
    assert Client(tr, tokens.issue("u")).version() == HOPAAS_VERSION
    with pytest.raises(ValueError, match="pool_size"):
        PooledHttpTransport(runner.host, runner.port, pool_size=0)


def test_pooled_close_reaps_in_flight_connections(service):
    """close() racing an in-flight request must not leave that request's
    socket open in the pool afterwards."""
    runner, tokens = service
    tr = PooledHttpTransport(runner.host, runner.port, pool_size=2)
    client = Client(tr, tokens.issue("u"))
    assert client.version() == HOPAAS_VERSION
    # simulate the race: box checked out while close() runs
    box = tr._pool.get()
    tr.close()
    status, _, _ = box.roundtrip("GET", "/api/version", None, None)
    assert status == 200
    if tr._closed:
        box.close()
    tr._pool.put(box)                     # the request_full finally-path
    assert all(b._conn is None for b in list(tr._pool.queue))
    # transport still usable after close (reconnects per request)
    assert client.version() == HOPAAS_VERSION


def test_pooled_survives_server_side_connection_close(service):
    """A pooled socket the server closed while idle reconnects
    transparently (same stale-retry contract as HttpTransport)."""
    runner, tokens = service
    tr = PooledHttpTransport(runner.host, runner.port, pool_size=1)
    client = Client(tr, tokens.issue("u"))
    assert client.version() == HOPAAS_VERSION
    # reach into the single pooled box and kill its socket the way a
    # server-side close does (EPIPE/RST on next send, fd still valid)
    box = tr._pool.get()
    assert box._conn is not None
    box._conn.sock.shutdown(socket.SHUT_RDWR)
    tr._pool.put(box)
    assert client.version() == HOPAAS_VERSION      # reconnect-once path
    tr.close()


# --------------------------------------------------------------------- #
# satellite: non-JSON response bodies become structured HopaasErrors
# --------------------------------------------------------------------- #
class _GarbageHttpServer:
    """Speaks just enough HTTP to return a non-JSON body (the shape of a
    proxy error page or a crashed upstream)."""

    def __init__(self, body=b"<html>502 Bad Gateway</html>", status=502):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind(("127.0.0.1", 0))
        self._sock.listen(8)
        self.host, self.port = self._sock.getsockname()[:2]
        self._body, self._status = body, status
        self._stop = False
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()

    def _serve(self):
        while not self._stop:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            try:
                conn.settimeout(2)
                data = b""
                while b"\r\n\r\n" not in data:
                    chunk = conn.recv(4096)
                    if not chunk:
                        break
                    data += chunk
                head = data.split(b"\r\n\r\n", 1)[0].decode("latin-1")
                length = 0
                for line in head.lower().split("\r\n"):
                    if line.startswith("content-length:"):
                        length = int(line.split(":", 1)[1])
                body_bytes = data.split(b"\r\n\r\n", 1)[1] \
                    if b"\r\n\r\n" in data else b""
                while len(body_bytes) < length:
                    body_bytes += conn.recv(4096)
                conn.sendall(
                    (f"HTTP/1.1 {self._status} Oops\r\n"
                     "Content-Type: text/html\r\n"
                     f"Content-Length: {len(self._body)}\r\n\r\n").encode()
                    + self._body)
            except OSError:
                pass
            finally:
                conn.close()

    def close(self):
        self._stop = True
        self._sock.close()


@pytest.mark.parametrize("make_transport", [
    lambda h, p: HttpTransport(h, p, timeout=5),
    lambda h, p: PooledHttpTransport(h, p, timeout=5, pool_size=2),
], ids=["single", "pooled"])
def test_non_json_body_raises_structured_hopaas_error(make_transport):
    srv = _GarbageHttpServer()
    try:
        tr = make_transport(srv.host, srv.port)
        with pytest.raises(HopaasError) as exc:
            tr.request("GET", "/api/version")
        err = exc.value
        assert err.status == 502
        assert err.code == "bad_upstream_body"
        assert "502 Bad Gateway" in str(err)       # body snippet surfaces
        assert "JSONDecodeError" not in str(err)
    finally:
        srv.close()


def test_non_json_body_is_not_retried_as_transport_failure():
    """The guard raises HopaasError, which the client's retry loop must
    NOT treat as a retryable connection error (one attempt only)."""
    srv = _GarbageHttpServer()
    try:
        tr = HttpTransport(srv.host, srv.port, timeout=5)
        client = Client(tr, "some-token")
        with pytest.raises(HopaasError, match="non-JSON body"):
            client.version()
    finally:
        srv.close()


def test_empty_body_still_parses_as_empty_payload(service):
    """A 0-byte body (e.g. from a proxy) maps to {} — not an error, and
    not a crash (regression guard for the old bare json.loads(b''))."""
    runner, tokens = service
    tr = HttpTransport(runner.host, runner.port)
    # the live service never sends empty bodies; exercise the parse
    # layer directly through the connection box
    from repro.core.transport import _PersistentConnection
    box = _PersistentConnection(runner.host, runner.port, timeout=5)
    status, payload, headers = box.roundtrip("GET", "/api/version", None, None)
    assert status == 200 and payload["version"] == HOPAAS_VERSION
    box.close()
