"""The event-loop HTTP frontend (PR 5 tentpole): wire parity with the
router across every method, concurrent keep-alive clients with no
response cross-talk, HTTP pipelining, Content-Length framing after
errors, the hot-GET response cache, and clean shutdown with in-flight
requests — plus the ``backend=`` switch itself."""
import http.client
import json
import socket
import threading
import time

import pytest

from repro.core import (Client, ClientStudy, HopaasServer, HOPAAS_VERSION,
                        HttpServiceRunner, HttpTransport, InMemoryStorage,
                        TokenManager, suggestions)

BACKENDS = ("evloop", "threaded")


def _service(backend="evloop", n_workers=2, seed=0):
    storage, tokens = InMemoryStorage(), TokenManager()
    workers = [HopaasServer(storage=storage, tokens=tokens, seed=seed + i)
               for i in range(n_workers)]
    return HttpServiceRunner(workers, backend=backend), tokens


def _raw(runner, blob: bytes, n_responses: int, timeout=10.0) -> bytes:
    """Send raw bytes, read until ``n_responses`` complete responses."""
    sk = socket.create_connection((runner.host, runner.port), timeout=timeout)
    try:
        sk.sendall(blob)
        data = b""
        deadline = time.time() + timeout
        while _count_responses(data) < n_responses:
            if time.time() > deadline:
                raise AssertionError(f"timed out; got {data!r}")
            chunk = sk.recv(65536)
            if not chunk:
                break
            data += chunk
        return data
    finally:
        sk.close()


def _count_responses(data: bytes) -> int:
    """Complete HTTP responses in ``data`` (Content-Length framed)."""
    n = 0
    while True:
        end = data.find(b"\r\n\r\n")
        if end < 0:
            return n
        head = data[:end].decode("latin-1").lower()
        length = 0
        for line in head.split("\r\n")[1:]:
            if line.startswith("content-length:"):
                length = int(line.split(":", 1)[1])
        if len(data) < end + 4 + length:
            return n
        data = data[end + 4 + length:]
        n += 1


# --------------------------------------------------------------------- #
# satellite: DELETE/PUT/PATCH/OPTIONS reach the router in both frontends
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method,path", [
    ("DELETE", "/api/v2/studies/deadbeef"),        # GET-only resource
    ("PUT", "/api/v2/trials:tell_batch"),          # POST-only action
    ("PATCH", "/api/v2/version"),
    ("OPTIONS", "/api/v2/studies"),
    ("DELETE", "/no/such/path"),                   # 404, not stdlib 501
])
def test_wire_parity_for_non_get_post_methods(backend, method, path):
    """Every method gets the *router's* answer on the wire — the stdlib
    501 for unimplemented do_* methods must never surface."""
    runner, tokens = _service(backend)
    runner.start()
    try:
        want = runner.workers[0].router.dispatch(method, path, None, {})
        conn = http.client.HTTPConnection(runner.host, runner.port,
                                          timeout=10)
        conn.request(method, path)
        resp = conn.getresponse()
        got_payload = json.loads(resp.read())
        got_headers = {k.lower(): v for k, v in resp.getheaders()}
        conn.close()
        status, payload, headers = want
        assert resp.status == status
        assert got_payload == payload
        for k, v in headers.items():            # e.g. the Allow list
            assert got_headers[k.lower()] == v
    finally:
        runner.stop()


@pytest.mark.parametrize("backend", BACKENDS)
def test_head_responses_carry_no_body(backend):
    """HEAD gets the router's status/headers but never a body (RFC 7231)
    — and keep-alive framing survives for the next request."""
    runner, tokens = _service(backend)
    runner.start()
    try:
        conn = http.client.HTTPConnection(runner.host, runner.port,
                                          timeout=10)
        conn.request("HEAD", "/api/version")
        resp = conn.getresponse()
        assert resp.status == 405                  # GET-only route
        assert resp.getheader("Allow") == "GET"
        assert int(resp.getheader("Content-Length")) > 0
        assert resp.read() == b""                  # headers only
        conn.request("GET", "/api/version")        # framing intact
        resp = conn.getresponse()
        assert resp.status == 200
        assert json.loads(resp.read())["version"] == HOPAAS_VERSION
        conn.close()
    finally:
        runner.stop()


def test_backpressure_bounds_unread_pipelined_responses():
    """A client that pipelines far more requests than it reads must not
    grow server buffers without bound: reading pauses at the high-water
    mark and resumes as the client drains, with every response intact."""
    from repro.core import aio
    n_requests = 8 * aio._MAX_PENDING
    # ~1KB per request so the burst spans many recv()s and the throttle
    # engages mid-stream instead of after one drained read
    request = (b"GET /api/version HTTP/1.1\r\nHost: x\r\nX-Pad: "
               + b"a" * 900 + b"\r\n\r\n")
    runner, tokens = _service("evloop")
    runner.start()
    try:
        sk = socket.create_connection((runner.host, runner.port),
                                      timeout=30)
        # a throttled server stops reading, so the blast must come from
        # a helper thread — sendall blocks once every buffer is full,
        # exactly like a real firehose client
        sender = threading.Thread(
            target=lambda: sk.sendall(request * n_requests), daemon=True)
        sender.start()
        time.sleep(0.5)                    # server hits the throttle
        conns = list(runner._frontend._conns.values())
        if conns:                          # still mid-stream
            # bounded: high-water mark plus at most one recv burst
            assert len(conns[0].pending) <= aio._MAX_PENDING + 100
            assert len(conns[0].outbuf) <= aio._MAX_OUTBUF + 4096
        data = b""
        deadline = time.time() + 30
        while _count_responses(data) < n_requests:
            assert time.time() < deadline, \
                f"only {_count_responses(data)}/{n_requests} responses"
            chunk = sk.recv(65536)
            assert chunk, "server closed mid-drain"
            data += chunk
        assert data.count(b'{"version"') == n_requests
        sender.join(timeout=10)
        assert not sender.is_alive()
        sk.close()
    finally:
        runner.stop()


@pytest.mark.parametrize("backend", BACKENDS)
def test_405_lists_allowed_methods(backend):
    runner, tokens = _service(backend)
    runner.start()
    try:
        conn = http.client.HTTPConnection(runner.host, runner.port,
                                          timeout=10)
        conn.request("DELETE", "/api/v2/studies/somekey")
        resp = conn.getresponse()
        body = json.loads(resp.read())
        assert resp.status == 405
        assert resp.getheader("Allow") == "GET"
        assert body["error"]["code"] == "method_not_allowed"
        # connection still framed: next request on the same socket works
        conn.request("GET", "/api/version")
        resp = conn.getresponse()
        assert resp.status == 200
        assert json.loads(resp.read())["version"] == HOPAAS_VERSION
        conn.close()
    finally:
        runner.stop()


# --------------------------------------------------------------------- #
# concurrent keep-alive clients: no cross-talk between responses
# --------------------------------------------------------------------- #
def test_concurrent_keepalive_no_cross_talk():
    """8 threads × 25 requests over persistent connections; every
    response body must match its own request (trial uid echo)."""
    runner, tokens = _service("evloop", n_workers=3)
    runner.start()
    try:
        tok = tokens.issue("u")
        seed_client = Client(HttpTransport(runner.host, runner.port), tok)
        uids = []
        for i in range(8):
            study = ClientStudy(name=f"xtalk-{i}", client=seed_client,
                                properties={"x": suggestions.uniform(0, 1)},
                                sampler={"name": "random"})
            uids.append(study.ask().uid)
        errors = []

        def worker(widx: int) -> None:
            client = Client(HttpTransport(runner.host, runner.port), tok)
            for _ in range(25):
                trial = client.trial(uids[widx])
                if trial["uid"] != uids[widx]:
                    errors.append((widx, trial["uid"]))

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
    finally:
        runner.stop()


def test_pipelined_requests_answered_in_order():
    """True HTTP pipelining: several requests written back-to-back on
    one socket; responses come back complete and in request order."""
    runner, tokens = _service("evloop")
    runner.start()
    try:
        tok = tokens.issue("u")
        client = Client(HttpTransport(runner.host, runner.port), tok)
        study = ClientStudy(name="pipe", client=client,
                            properties={"x": suggestions.uniform(0, 1)},
                            sampler={"name": "random"})
        uids = [t.uid for t in study.ask_batch(3)]
        # 3 trial GETs for distinct uids + 1 version GET, one write
        reqs = b"".join(
            (f"GET /api/v2/trials/{uid} HTTP/1.1\r\nHost: x\r\n"
             f"Authorization: Bearer {tok}\r\n\r\n").encode()
            for uid in uids) + b"GET /api/version HTTP/1.1\r\nHost: x\r\n\r\n"
        data = _raw(runner, reqs, n_responses=4)
        bodies = _parse_bodies(data)
        assert len(bodies) == 4
        assert [b["trial"]["uid"] for b in bodies[:3]] == uids
        assert bodies[3] == {"version": HOPAAS_VERSION}
    finally:
        runner.stop()


def _parse_bodies(data: bytes) -> list[dict]:
    bodies = []
    while data:
        end = data.find(b"\r\n\r\n")
        if end < 0:
            break
        head = data[:end].decode("latin-1").lower()
        length = 0
        for line in head.split("\r\n")[1:]:
            if line.startswith("content-length:"):
                length = int(line.split(":", 1)[1])
        bodies.append(json.loads(data[end + 4:end + 4 + length]))
        data = data[end + 4 + length:]
    return bodies


def test_framing_survives_422_and_interleaved_errors():
    """A schema 422 and a malformed-JSON 400 must leave the connection
    correctly framed for the next pipelined/keep-alive request."""
    runner, tokens = _service("evloop")
    runner.start()
    try:
        tok = tokens.issue("u")
        conn = http.client.HTTPConnection(runner.host, runner.port,
                                          timeout=10)
        # non-dict JSON body -> 422 naming "$"
        conn.request("POST", f"/api/tell/{tok}", body=b"[1,2,3]",
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        assert resp.status == 422
        assert json.loads(resp.read())["error"]["field"] == "$"
        # malformed JSON -> 400, same connection
        conn.request("POST", f"/api/tell/{tok}", body=b"{nope",
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        assert resp.status == 400
        assert json.loads(resp.read())["error"]["code"] == "invalid_json"
        # and the connection is still perfectly usable
        conn.request("GET", "/api/version")
        resp = conn.getresponse()
        assert resp.status == 200
        assert json.loads(resp.read())["version"] == HOPAAS_VERSION
        conn.close()
    finally:
        runner.stop()


def test_malformed_request_line_gets_400_then_close():
    runner, tokens = _service("evloop")
    runner.start()
    try:
        data = _raw(runner, b"BLARGH\r\n\r\n", n_responses=1)
        assert data.startswith(b"HTTP/1.1 400 ")
        assert b"Connection: close" in data
    finally:
        runner.stop()


# --------------------------------------------------------------------- #
# hot-GET response cache: keyed on data_version, never stale
# --------------------------------------------------------------------- #
def test_study_resource_cache_tracks_mutations():
    runner, tokens = _service("evloop")
    runner.start()
    try:
        tok = tokens.issue("u")
        client = Client(HttpTransport(runner.host, runner.port), tok)
        study = ClientStudy(name="cache", client=client,
                            properties={"x": suggestions.uniform(0, 1)},
                            sampler={"name": "random"})
        trial = study.ask()
        first = client.study(study.study_key)          # fills the cache
        again = client.study(study.study_key)          # served from cache
        assert again == first
        hits0 = runner.frontend_stats()["cache_hits"]
        assert hits0 >= 1
        client.tell(trial.uid, value=0.25)             # bumps data_version
        after = client.study(study.study_key)          # cache must miss
        assert after["n_completed"] == first["n_completed"] + 1
        assert after["best_value"] == 0.25
        assert after["data_version"] > first["data_version"]
    finally:
        runner.stop()


def test_cached_study_get_still_requires_auth():
    runner, tokens = _service("evloop")
    runner.start()
    try:
        tok = tokens.issue("u")
        client = Client(HttpTransport(runner.host, runner.port), tok)
        study = ClientStudy(name="authed", client=client,
                            properties={"x": suggestions.uniform(0, 1)},
                            sampler={"name": "random"})
        study.ask()
        client.study(study.study_key)                  # cache filled
        bare = HttpTransport(runner.host, runner.port)
        status, payload = bare.request(
            "GET", f"/api/v2/studies/{study.study_key}")
        assert status == 401
        assert payload["error"]["code"] == "unauthorized"
        status, payload = bare.request(
            "GET", f"/api/v2/studies/{study.study_key}",
            headers={"Authorization": "Bearer garbage"})
        assert status == 401
    finally:
        runner.stop()


# --------------------------------------------------------------------- #
# shutdown: in-flight requests complete, stop() never hangs
# --------------------------------------------------------------------- #
class _SlowServer(HopaasServer):
    def handle_request(self, *args, **kwargs):
        time.sleep(0.4)
        return super().handle_request(*args, **kwargs)


def test_clean_shutdown_with_in_flight_requests():
    storage, tokens = InMemoryStorage(), TokenManager()
    runner = HttpServiceRunner(
        [_SlowServer(storage=storage, tokens=tokens)],
        backend="evloop").start()
    results = []

    def hit():
        conn = http.client.HTTPConnection(runner.host, runner.port,
                                          timeout=10)
        conn.request("GET", "/api/v2/version")
        resp = conn.getresponse()
        results.append((resp.status, json.loads(resp.read())))
        conn.close()

    threads = [threading.Thread(target=hit) for _ in range(3)]
    for t in threads:
        t.start()
    time.sleep(0.15)                # requests are now in flight
    t0 = time.time()
    runner.stop()                   # must drain, not drop
    assert time.time() - t0 < 5.0
    for t in threads:
        t.join(timeout=5)
    assert len(results) == 3
    assert all(status == 200 for status, _ in results)
    assert all(body["version"] == HOPAAS_VERSION for _, body in results)


def test_stop_with_idle_keepalive_connections_is_fast():
    runner, tokens = _service("evloop")
    runner.start()
    tr = HttpTransport(runner.host, runner.port)
    assert tr.request("GET", "/api/version")[0] == 200   # socket now idle
    t0 = time.time()
    runner.stop()
    assert time.time() - t0 < 2.0


# --------------------------------------------------------------------- #
# the backend switch
# --------------------------------------------------------------------- #
def test_backend_env_switch(monkeypatch):
    monkeypatch.setenv("REPRO_FRONTEND", "threaded")
    runner, _ = _service(backend=None)
    assert runner.backend == "threaded"
    runner._frontend.httpd.server_close()
    monkeypatch.delenv("REPRO_FRONTEND")
    runner, _ = _service(backend=None)
    assert runner.backend == "evloop"
    runner.stop()


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown frontend backend"):
        _service(backend="uvicorn")


@pytest.mark.parametrize("backend", BACKENDS)
def test_payloads_identical_across_frontends(backend):
    """The wire payload equals the router's in-process payload exactly
    (the fast path may change encoding whitespace, never content)."""
    runner, tokens = _service(backend)
    runner.start()
    try:
        tok = tokens.issue("u")
        client = Client(HttpTransport(runner.host, runner.port), tok)
        study = ClientStudy(name="ident", client=client,
                            properties={"x": suggestions.uniform(0, 1)},
                            sampler={"name": "random"})
        trial = study.ask()
        client.tell(trial.uid, value=0.5)
        for method, path in (("GET", "/api/version"),
                             ("GET", f"/api/v2/studies/{study.study_key}"),
                             ("GET", f"/api/v2/trials/{trial.uid}")):
            headers = {"Authorization": f"Bearer {tok}"}
            wire = HttpTransport(runner.host, runner.port).request(
                method, path, headers=headers)
            direct = runner.workers[0].handle_request(
                method, path, None, headers)[:2]
            assert wire == direct
    finally:
        runner.stop()
