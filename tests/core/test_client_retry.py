"""Client-side retry: exponential backoff + jitter on connection resets
and 503s for idempotent calls; every tell carries an idempotency key
that is constant across retries, so a resend after a lost response
replays the original result server-side (exactly-once) instead of
tripping the duplicate-finalize 409."""
import pytest

from repro.core import (Client, ClientStudy, DirectTransport, HopaasError,
                        HopaasServer, RetryPolicy, Transport, suggestions)

FAST = RetryPolicy(max_attempts=4, base_delay=0.0, max_delay=0.0)


class FlakyTransport(Transport):
    """Raises/injects failures for the first ``fail`` requests, then
    delegates to a real DirectTransport."""

    def __init__(self, server, fail: int, mode: str = "reset"):
        self.inner = DirectTransport(server)
        self.remaining = fail
        self.mode = mode
        self.attempts = 0

    def request_full(self, method, path, body=None, headers=None):
        self.attempts += 1
        if self.remaining > 0:
            self.remaining -= 1
            if self.mode == "reset":
                raise ConnectionResetError("connection reset by peer")
            return 503, {"detail": "service unavailable"}, {}
        return self.inner.request_full(method, path, body, headers)


class LostResponseTransport(Transport):
    """Processes the request server-side but 'loses' the response —
    the client cannot tell whether the call landed."""

    def __init__(self, server, lose: int):
        self.inner = DirectTransport(server)
        self.lose = lose

    def request_full(self, method, path, body=None, headers=None):
        out = self.inner.request_full(method, path, body, headers)
        if self.lose > 0:
            self.lose -= 1
            raise ConnectionResetError("reset after send")
        return out


def _server():
    return HopaasServer(seed=0)


def _study(client, name="r"):
    return ClientStudy(name=name,
                       properties={"x": suggestions.uniform(0, 1)},
                       sampler={"name": "random"}, client=client)


@pytest.mark.parametrize("mode", ["reset", "503"])
def test_ask_retries_through_transient_failures(mode):
    srv = _server()
    tr = FlakyTransport(srv, fail=2, mode=mode)
    client = Client(tr, srv.tokens.issue("u"), retry=FAST)
    t = _study(client).ask()
    assert 0.0 <= t.x <= 1.0
    # create_study burned the first two failures, so > 2 total requests
    assert tr.attempts > 2


def test_retries_exhausted_raises(_mode="reset"):
    srv = _server()
    tr = FlakyTransport(srv, fail=99, mode="reset")
    client = Client(tr, srv.tokens.issue("u"), retry=FAST)
    with pytest.raises(HopaasError, match="transport failure"):
        _study(client).ask()
    assert tr.attempts == FAST.max_attempts


def test_503_exhaustion_surfaces_the_503():
    srv = _server()
    tr = FlakyTransport(srv, fail=99, mode="503")
    client = Client(tr, srv.tokens.issue("u"), retry=FAST)
    with pytest.raises(HopaasError, match="503"):
        _study(client).ask()


def test_tell_conflict_after_retry_is_success():
    """The response to the first tell is lost; the retry carries the
    same idempotency key, so the server recognizes the resend and
    replays the original result — no error, no double-apply."""
    srv = _server()
    setup = Client(DirectTransport(srv), srv.tokens.issue("u"), retry=FAST)
    study = _study(setup)
    trial = study.ask()

    lossy = Client(LostResponseTransport(srv, lose=1),
                   srv.tokens.issue("u"), retry=FAST)
    lossy.tell(trial.uid, value=0.7)        # no raise
    stored = srv.storage.get_trial(trial.uid)
    assert stored.state.value == "completed" and stored.value == 0.7


def test_tell_conflict_after_503_retry_still_raises():
    """A 503 means the server never processed the first attempt, so the
    retry's idempotency key is unseen: the 409 it hits is a genuine
    conflict (someone else finalized the trial) and must surface."""
    srv = _server()
    setup = Client(DirectTransport(srv), srv.tokens.issue("u"), retry=FAST)
    study = _study(setup)
    t = study.ask()
    study.tell(t, value=1.0)            # someone else finalizes the trial

    tr = FlakyTransport(srv, fail=1, mode="503")
    flaky = Client(tr, srv.tokens.issue("u"), retry=FAST)
    with pytest.raises(HopaasError, match="409"):
        flaky.tell(t.uid, value=2.0)
    assert srv.storage.get_trial(t.uid).value == 1.0


def test_tell_conflict_after_retry_returns_real_state():
    """The recovered 'success' is the trial's actual resource, not the
    conflict envelope."""
    srv = _server()
    setup = Client(DirectTransport(srv), srv.tokens.issue("u"), retry=FAST)
    study = _study(setup)
    trial = study.ask()
    lossy = Client(LostResponseTransport(srv, lose=1),
                   srv.tokens.issue("u"), retry=FAST)
    out = lossy.tell(trial.uid, value=0.3)
    assert out["uid"] == trial.uid and out["state"] == "completed"


def test_plain_tell_conflict_still_raises():
    srv = _server()
    client = Client(DirectTransport(srv), srv.tokens.issue("u"), retry=FAST)
    study = _study(client)
    t = study.ask()
    study.tell(t, value=1.0)
    with pytest.raises(HopaasError, match="409"):
        study.tell(t, value=2.0)


def test_backoff_delays_grow_and_jitter():
    policy = RetryPolicy(max_attempts=5, base_delay=0.1, max_delay=10.0)
    d1 = [policy.delay(1) for _ in range(50)]
    d3 = [policy.delay(3) for _ in range(50)]
    # full jitter inside [cap/2, cap]
    assert all(0.05 <= d <= 0.1 for d in d1)
    assert all(0.2 <= d <= 0.4 for d in d3)
    assert len({round(d, 6) for d in d1}) > 1      # actually jittered
    # cap respected
    assert all(policy.delay(30) <= 10.0 for _ in range(10))


def test_non_idempotent_legacy_post_does_not_retry():
    srv = _server()
    tr = FlakyTransport(srv, fail=1, mode="reset")
    client = Client(tr, srv.tokens.issue("u"), retry=FAST)
    with pytest.raises(HopaasError, match="transport failure"):
        client._post("ask", {"name": "x", "properties": {}})
    assert tr.attempts == 1
