"""Sampler correctness + the paper's Bayesian-beats-random claim."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.samplers import make_sampler
from repro.core.space import Param, SearchSpace
from repro.core.types import Direction, Trial, TrialState

SPACE_2D = {"x": {"type": "uniform", "low": -5, "high": 5},
            "y": {"type": "uniform", "low": -5, "high": 5}}


def optimize(sampler_spec, fn, n, seed, properties=SPACE_2D,
             direction=Direction.MINIMIZE):
    space = SearchSpace.from_properties(properties)
    sampler = make_sampler(dict(sampler_spec))
    rng = np.random.default_rng(seed)
    trials, best = [], math.inf
    for i in range(n):
        p = sampler.suggest(space, trials, direction, rng)
        v = fn(**{k: p[k] for k in ("x", "y") if k in p})
        trials.append(Trial(trial_id=i, uid=f"s:{i}", study_key="s", params=p,
                            state=TrialState.COMPLETED, value=v))
        best = min(best, v)
    return best, trials


def quad(x, y):
    return (x - 1.0) ** 2 + (y + 2.0) ** 2


@pytest.mark.parametrize("name", ["random", "grid", "halton", "tpe", "gp", "cmaes"])
def test_sampler_respects_space(name):
    space = SearchSpace.from_properties(
        {"x": {"type": "uniform", "low": -5, "high": 5},
         "y": {"type": "uniform", "low": -5, "high": 5},
         "n": {"type": "int", "low": 2, "high": 9},
         "c": {"type": "categorical", "choices": ["a", "b", "c"]}})
    sampler = make_sampler({"name": name})
    rng = np.random.default_rng(0)
    trials = []
    for i in range(25):
        p = sampler.suggest(space, trials, Direction.MINIMIZE, rng)
        assert -5 <= p["x"] <= 5 and -5 <= p["y"] <= 5
        assert 2 <= p["n"] <= 9 and isinstance(p["n"], int)
        assert p["c"] in ("a", "b", "c")
        trials.append(Trial(trial_id=i, uid=f"s:{i}", study_key="s", params=p,
                            state=TrialState.COMPLETED,
                            value=float(p["x"] ** 2 + p["y"] ** 2)))


@pytest.mark.parametrize("name", ["tpe", "gp", "cmaes"])
def test_bayesian_beats_random_on_quadratic(name):
    """Paper sec. 1: BO 'focuses on regions where the model performs better'.
    Median over seeds must beat random search at equal budget."""
    seeds = range(6)
    rand = np.median([optimize({"name": "random"}, quad, 60, s)[0] for s in seeds])
    bayes = np.median([optimize({"name": name, "seed": s} if name != "cmaes"
                                else {"name": name}, quad, 60, s)[0] for s in seeds])
    assert bayes < rand, f"{name}: {bayes} !< {rand}"


def test_maximize_direction():
    best, trials = optimize({"name": "gp"}, lambda x, y: -quad(x, y), 40, 0,
                            direction=Direction.MAXIMIZE)
    values = [t.value for t in trials]
    assert max(values) > -1.0      # found a point near the optimum (0)


def test_grid_covers_lattice():
    space = SearchSpace.from_properties(
        {"x": {"type": "uniform", "low": 0, "high": 1},
         "c": {"type": "categorical", "choices": ["a", "b"]}})
    sampler = make_sampler({"name": "grid", "points_per_dim": 3})
    rng = np.random.default_rng(0)
    seen = set()
    trials = []
    for i in range(6):
        p = sampler.suggest(space, trials, Direction.MINIMIZE, rng)
        seen.add((p["c"], round(p["x"], 6)))
        trials.append(Trial(trial_id=i, uid=f"g:{i}", study_key="g", params=p,
                            state=TrialState.COMPLETED, value=0.0))
    assert len(seen) == 6          # full 2x3 lattice, no repeats


def test_halton_low_discrepancy():
    """First 64 Halton points cover [0,1]^2 better than the worst uniform."""
    sampler = make_sampler({"name": "halton"})
    pts = np.stack([sampler.point(i, 2) for i in range(64)])
    # each quadrant gets a fair share
    for qx in (0, 1):
        for qy in (0, 1):
            n = np.sum((pts[:, 0] >= qx * .5) & (pts[:, 0] < qx * .5 + .5) &
                       (pts[:, 1] >= qy * .5) & (pts[:, 1] < qy * .5 + .5))
            assert 8 <= n <= 24


# ---------------------- property-based space tests ----------------------
@given(low=st.floats(-1e3, 1e3), width=st.floats(1e-3, 1e3),
       u=st.floats(0, 1))
@settings(max_examples=200, deadline=None)
def test_uniform_roundtrip(low, width, u):
    p = Param(name="p", kind="uniform", low=low, high=low + width)
    v = p.from_unit(u)
    assert low - 1e-6 <= v <= low + width + 1e-6
    assert abs(p.to_unit(v) - u) < 1e-6


@given(low=st.floats(1e-6, 1e3), ratio=st.floats(1.001, 1e6),
       u=st.floats(0, 1))
@settings(max_examples=200, deadline=None)
def test_loguniform_roundtrip(low, ratio, u):
    p = Param(name="p", kind="loguniform", low=low, high=low * ratio)
    v = p.from_unit(u)
    assert low * 0.999 <= v <= low * ratio * 1.001
    assert abs(p.to_unit(v) - u) < 1e-5


@given(low=st.integers(-100, 100), width=st.integers(1, 200),
       u=st.floats(0, 1))
@settings(max_examples=200, deadline=None)
def test_int_roundtrip(low, width, u):
    p = Param(name="p", kind="int", low=low, high=low + width)
    v = p.from_unit(u)
    assert isinstance(v, int) and low <= v <= low + width


@given(n=st.integers(1, 10), u=st.floats(0, 1))
@settings(max_examples=100, deadline=None)
def test_categorical_roundtrip(n, u):
    choices = tuple(f"c{i}" for i in range(n))
    p = Param(name="p", kind="categorical", choices=choices)
    assert p.from_unit(u) in choices


@given(st.lists(st.floats(0, 1), min_size=2, max_size=2))
@settings(max_examples=50, deadline=None)
def test_vector_roundtrip(us):
    space = SearchSpace.from_properties(SPACE_2D)
    params = space.from_unit_vector(np.array(us))
    back = space.to_unit_vector(params)
    np.testing.assert_allclose(back, np.clip(us, 0, 1), atol=1e-9)
