"""Sampler correctness + the paper's Bayesian-beats-random claim."""
import math

import numpy as np
import pytest

from repro.core.samplers import make_sampler
from repro.core.space import SearchSpace
from repro.core.types import Direction, Trial, TrialState

SPACE_2D = {"x": {"type": "uniform", "low": -5, "high": 5},
            "y": {"type": "uniform", "low": -5, "high": 5}}


def optimize(sampler_spec, fn, n, seed, properties=SPACE_2D,
             direction=Direction.MINIMIZE):
    space = SearchSpace.from_properties(properties)
    sampler = make_sampler(dict(sampler_spec))
    rng = np.random.default_rng(seed)
    trials, best = [], math.inf
    for i in range(n):
        p = sampler.suggest(space, trials, direction, rng)
        v = fn(**{k: p[k] for k in ("x", "y") if k in p})
        trials.append(Trial(trial_id=i, uid=f"s:{i}", study_key="s", params=p,
                            state=TrialState.COMPLETED, value=v))
        best = min(best, v)
    return best, trials


def quad(x, y):
    return (x - 1.0) ** 2 + (y + 2.0) ** 2


@pytest.mark.parametrize("name", ["random", "grid", "halton", "tpe", "gp", "cmaes"])
def test_sampler_respects_space(name):
    space = SearchSpace.from_properties(
        {"x": {"type": "uniform", "low": -5, "high": 5},
         "y": {"type": "uniform", "low": -5, "high": 5},
         "n": {"type": "int", "low": 2, "high": 9},
         "c": {"type": "categorical", "choices": ["a", "b", "c"]}})
    sampler = make_sampler({"name": name})
    rng = np.random.default_rng(0)
    trials = []
    for i in range(25):
        p = sampler.suggest(space, trials, Direction.MINIMIZE, rng)
        assert -5 <= p["x"] <= 5 and -5 <= p["y"] <= 5
        assert 2 <= p["n"] <= 9 and isinstance(p["n"], int)
        assert p["c"] in ("a", "b", "c")
        trials.append(Trial(trial_id=i, uid=f"s:{i}", study_key="s", params=p,
                            state=TrialState.COMPLETED,
                            value=float(p["x"] ** 2 + p["y"] ** 2)))


@pytest.mark.parametrize("name", ["tpe", "gp", "cmaes"])
def test_bayesian_beats_random_on_quadratic(name):
    """Paper sec. 1: BO 'focuses on regions where the model performs better'.
    Median over seeds must beat random search at equal budget."""
    seeds = range(6)
    rand = np.median([optimize({"name": "random"}, quad, 60, s)[0] for s in seeds])
    bayes = np.median([optimize({"name": name, "seed": s} if name != "cmaes"
                                else {"name": name}, quad, 60, s)[0] for s in seeds])
    assert bayes < rand, f"{name}: {bayes} !< {rand}"


def test_maximize_direction():
    best, trials = optimize({"name": "gp"}, lambda x, y: -quad(x, y), 40, 0,
                            direction=Direction.MAXIMIZE)
    values = [t.value for t in trials]
    assert max(values) > -1.0      # found a point near the optimum (0)


def test_grid_covers_lattice():
    space = SearchSpace.from_properties(
        {"x": {"type": "uniform", "low": 0, "high": 1},
         "c": {"type": "categorical", "choices": ["a", "b"]}})
    sampler = make_sampler({"name": "grid", "points_per_dim": 3})
    rng = np.random.default_rng(0)
    seen = set()
    trials = []
    for i in range(6):
        p = sampler.suggest(space, trials, Direction.MINIMIZE, rng)
        seen.add((p["c"], round(p["x"], 6)))
        trials.append(Trial(trial_id=i, uid=f"g:{i}", study_key="g", params=p,
                            state=TrialState.COMPLETED, value=0.0))
    assert len(seen) == 6          # full 2x3 lattice, no repeats


def test_halton_low_discrepancy():
    """First 64 Halton points cover [0,1]^2 better than the worst uniform."""
    sampler = make_sampler({"name": "halton"})
    pts = np.stack([sampler.point(i, 2) for i in range(64)])
    # each quadrant gets a fair share
    for qx in (0, 1):
        for qy in (0, 1):
            n = np.sum((pts[:, 0] >= qx * .5) & (pts[:, 0] < qx * .5 + .5) &
                       (pts[:, 1] >= qy * .5) & (pts[:, 1] < qy * .5 + .5))
            assert 8 <= n <= 24
