"""Fault-injection harness (ISSUE PR 8 satellite): every named injector
site compiled into the production paths is (a) reachable from normal
operation and (b) deterministic under a fixed seed.

Sites under test (see ``repro.core.faults``):
  crash_before_fsync / crash_after_fsync  -> durable._ensure_durable
  torn_ship (torn | bitflip)              -> replication hub _ship
  partition_follower                      -> ReplicationClient._sync_once
  lease_skew                              -> server._lease_deadline
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core import (Client, ClientStudy, DirectTransport, DurableStorage,
                        HopaasServer, InMemoryStorage, ReplicationClient,
                        ReplicationHub, recover_dir_state, suggestions)
from repro.core import faults
from repro.core.faults import FaultInjector

_SPACE = {"x": suggestions.uniform(0.0, 1.0)}


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.install({})
    yield
    faults.install({})


# --------------------------------------------------------------------- #
# injector semantics: seeded determinism
# --------------------------------------------------------------------- #
def test_torn_mangle_deterministic_under_fixed_seed():
    data = bytes(range(256))
    spec = {"torn_ship": {"mode": "always", "arg": "torn"}}
    a = FaultInjector(spec, seed=11).mangle("torn_ship", data)
    b = FaultInjector(spec, seed=11).mangle("torn_ship", data)
    assert a == b                        # replayable chaos
    assert a == data[:len(a)] and len(a) < len(data)   # strict prefix


def test_bitflip_mangle_flips_exactly_one_bit():
    data = bytes(range(256))
    spec = {"torn_ship": {"mode": "always", "arg": "bitflip"}}
    a = FaultInjector(spec, seed=3).mangle("torn_ship", data)
    b = FaultInjector(spec, seed=3).mangle("torn_ship", data)
    assert a == b and len(a) == len(data)
    diffs = [(x, y) for x, y in zip(a, data) if x != y]
    assert len(diffs) == 1 and diffs[0][0] ^ diffs[0][1] == 0x40


def test_nth_mode_counts_every_arrival():
    inj = FaultInjector({"f": {"mode": "nth", "n": 3}})
    assert [inj.fire("f") for _ in range(5)] == [False, False, True,
                                                False, False]
    assert inj.stats()["arrivals"]["f"] == 5


def test_once_mode_fires_exactly_once():
    inj = FaultInjector({"f": {"mode": "once"}})
    assert [inj.fire("f") for _ in range(4)] == [True, False, False, False]


def test_context_filter_gates_firing():
    inj = FaultInjector({"f": {"mode": "always", "worker": 1,
                               "role": "leader"}})
    assert not inj.fire("f")             # no context set
    inj.set_context(worker=1, role="follower")
    assert not inj.fire("f")             # wrong role
    inj.set_context(role="leader")
    assert inj.fire("f")
    # arrivals counted even while filtered: nth stays deterministic
    assert inj.stats()["arrivals"]["f"] == 3


def test_skew_returns_armed_arg_else_zero():
    inj = FaultInjector({"lease_skew": {"mode": "always", "arg": -30.0}})
    assert inj.skew("lease_skew") == -30.0
    assert FaultInjector().skew("lease_skew") == 0.0


def test_env_spec_arms_process_injector():
    env = {faults.ENV_VAR: json.dumps(
        {"seed": 3, "faults": {"lease_skew": {"mode": "always",
                                              "arg": 1.5}}})}
    inj = faults.load_from_env(env)
    assert inj is faults.injector()
    assert inj.stats()["armed"] == ["lease_skew"]
    assert faults.skew("lease_skew") == 1.5


# --------------------------------------------------------------------- #
# reachability: normal operation routes through every site
# --------------------------------------------------------------------- #
def test_every_injection_site_is_reached_by_normal_operation(tmp_path):
    """Disarmed injectors still count arrivals, so one end-to-end drive
    (durable server + replicated follower) proves each named site sits
    on a live code path — a renamed site fails here, not in a chaos run
    that silently stops injecting."""
    storage = DurableStorage(str(tmp_path / "leader"), fsync="always",
                             auto_compact=False)
    hub = ReplicationHub(storage)
    storage.attach_replicator(hub)
    srv = HopaasServer(storage=storage, seed=0, lease_seconds=60.0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="sites", client=cl, properties=dict(_SPACE),
                        sampler={"name": "random"})
    shadow = InMemoryStorage()
    client = ReplicationClient(shadow, ("127.0.0.1", hub.port)).start()
    try:
        t = study.ask()
        study.tell(t, value=abs(t.x))
        assert client.wait_position(hub.position(), timeout=15.0)
    finally:
        client.stop()
        hub.stop()
        storage.close()
    arrivals = faults.injector().stats()["arrivals"]
    for site in ("crash_before_fsync", "crash_after_fsync", "lease_skew",
                 "torn_ship", "partition_follower"):
        assert arrivals.get(site, 0) >= 1, (site, arrivals)


# --------------------------------------------------------------------- #
# crash_after_fsync: the durable sibling of the existing
# crash-before test in test_replication.py
# --------------------------------------------------------------------- #
def test_crash_after_fsync_recovers_everything_acked(tmp_path):
    """Dying right *after* the fsync syscall is the friendliest crash:
    the synced batch is on stable storage, so recovery must cover every
    acknowledged write — and the process must still die with the
    injector's exit code, proving the site fired (not just counted)."""
    root = str(tmp_path / "crashy")
    prog = (
        "import repro.core.faults as f\n"
        "f.load_from_env()\n"
        "from repro.core import HopaasServer, DurableStorage\n"
        "srv = HopaasServer(storage=DurableStorage(%r, fsync='always',"
        " auto_compact=False), seed=0)\n"
        "cfg = {'name': 'c', 'properties': {'x': {'type': 'uniform',"
        " 'low': 0, 'high': 1}}, 'sampler': {'name': 'random'}}\n"
        "_created, res = srv.op_create_study(cfg)\n"
        "key = res['key']\n"
        "for i in range(50):\n"
        "    (t,) = srv.op_ask(key, 'w', 1)\n"
        "    srv.op_tell(t['uid'], float(i), 'completed')\n"
        "    print(t['uid'], flush=True)\n"
    ) % root
    import repro.core
    src_dir = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(repro.core.__file__))))
    env = dict(os.environ, REPRO_FAULTS=json.dumps(
        {"faults": {"crash_after_fsync": {"mode": "nth", "n": 30}}}))
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", prog], env=env,
                          capture_output=True, text=True, timeout=60)
    assert proc.returncode == 137, proc.stderr
    acked = [ln for ln in proc.stdout.splitlines() if ln.strip()]
    assert acked
    store, _meta = recover_dir_state(root)
    have = {t.uid for s in store.studies() for t in s.trials}
    assert set(acked) <= have, sorted(set(acked) - have)
