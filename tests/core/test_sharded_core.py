"""Sharded storage internals: lease-heap expiry ordering, state indices,
journal gap padding, and journal replay of a full faulty campaign."""
import json
import time

from repro.core import (Client, ClientStudy, DirectTransport, HopaasServer,
                        InMemoryStorage, JournalStorage, run_campaign,
                        suggestions)
from repro.core.types import StudyConfig, TrialState

PROPS = {"x": {"type": "uniform", "low": 0.0, "high": 1.0}}


def _make_study(storage):
    config = StudyConfig(name="s", properties=PROPS)
    study, _ = storage.get_or_create_study(config)
    return study.key


# ---------------------------------------------------------------- lease heap
def test_pop_expired_returns_deadline_order():
    storage = InMemoryStorage()
    key = _make_study(storage)
    now = time.time()
    deadlines = [now - 3.0, now - 1.0, now - 2.0, now + 60.0]
    for dl in deadlines:
        storage.add_trial(key, {"x": 0.5}, worker_id="w", lease_deadline=dl)
    expired = storage.pop_expired(key, now)
    assert [t.trial_id for t in expired] == [0, 2, 1]     # soonest first
    assert storage.pop_expired(key, now) == []            # heap drained
    # the live-lease trial is untouched
    assert storage.get_trial(f"{key}:3").state == TrialState.RUNNING


def test_lease_renewal_supersedes_old_heap_entry():
    storage = InMemoryStorage()
    key = _make_study(storage)
    now = time.time()
    t = storage.add_trial(key, {"x": 0.5}, worker_id="w",
                          lease_deadline=now - 1.0)
    # heartbeat: renew past the sweep horizon
    storage.update_trial(t.uid, lease_deadline=now + 60.0)
    assert storage.lease_heap_size(key) == 2              # old + renewed entry
    assert storage.pop_expired(key, now) == []            # stale entry dropped
    assert storage.lease_heap_size(key) == 1              # live lease remains
    assert storage.get_trial(t.uid).state == TrialState.RUNNING


def test_finalized_trial_never_reported_expired():
    storage = InMemoryStorage()
    key = _make_study(storage)
    now = time.time()
    t = storage.add_trial(key, {"x": 0.1}, worker_id="w",
                          lease_deadline=now - 1.0)
    storage.update_trial(t.uid, state=TrialState.COMPLETED, value=0.1,
                         lease_deadline=None)
    assert storage.pop_expired(key, now) == []


def test_state_indices_track_transitions():
    storage = InMemoryStorage()
    key = _make_study(storage)
    trials = [storage.add_trial(key, {"x": i / 4}, worker_id="w",
                                lease_deadline=None) for i in range(4)]
    storage.update_trial(trials[0].uid, state=TrialState.COMPLETED)
    storage.update_trial(trials[1].uid, state=TrialState.PRUNED)
    storage.update_trial(trials[2].uid, state=TrialState.FAILED)
    counts = storage.counts(key)
    assert counts[TrialState.COMPLETED] == 1
    assert counts[TrialState.PRUNED] == 1
    assert counts[TrialState.FAILED] == 1
    assert counts[TrialState.RUNNING] == 1
    assert {t.trial_id for t in
            storage.trials_in_state(key, TrialState.RUNNING)} == {3}


def test_sweep_is_per_study():
    """A sweep triggered by one study's ask must not scan or mutate other
    studies (the old global-scan behavior)."""
    srv = HopaasServer(lease_seconds=0.01, seed=0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    a = ClientStudy(name="a", client=cl, properties=PROPS,
                    sampler={"name": "random"})
    b = ClientStudy(name="b", client=cl, properties=PROPS,
                    sampler={"name": "random"})
    ta, tb = a.ask(), b.ask()
    time.sleep(0.03)
    assert srv.sweep_expired(ta.uid.partition(":")[0]) == 1
    assert srv.storage.get_trial(ta.uid).state == TrialState.FAILED
    assert srv.storage.get_trial(tb.uid).state == TrialState.RUNNING


# ------------------------------------------------------------------ journal
def test_journal_gap_padding_regression(tmp_path):
    """A journal gap (lost add_trial record) must pad with tombstones, not
    duplicate the next trial object across slots (old bug: uid->trial
    lookups of the padded slots returned the wrong trial)."""
    path = str(tmp_path / "gap.jsonl")
    config = StudyConfig(name="g", properties=PROPS)
    key = config.key()
    mem = InMemoryStorage()
    mem.get_or_create_study(config)
    real = {"op": "add_trial",
            "trial": {"trial_id": 2, "uid": f"{key}:2", "study_key": key,
                      "params": {"x": 0.9}, "state": "running", "value": None,
                      "values": None, "intermediates": {}, "worker_id": "w",
                      "lease_deadline": None, "created_at": time.time(),
                      "finished_at": None, "retries": 0}}
    with open(path, "w") as f:
        f.write(json.dumps({"op": "create_study",
                            "config": config.to_record()}) + "\n")
        f.write(json.dumps(real) + "\n")

    storage = JournalStorage(path)
    study = storage.get_study(key)
    assert len(study.trials) == 3
    for i in (0, 1):                      # padded slots: explicit tombstones
        pad = storage.get_trial(f"{key}:{i}")
        assert pad.trial_id == i and pad.uid == f"{key}:{i}"
        assert pad.state == TrialState.FAILED and pad.params == {}
    survivor = storage.get_trial(f"{key}:2")
    assert survivor.trial_id == 2 and survivor.params == {"x": 0.9}
    storage.close()


def _objective(params, report):
    val = (params["x"] - 0.3) ** 2
    for step in range(3):
        if report(step, val + (3 - step) * 0.05):
            return val
    return val


def test_journal_replay_roundtrip_through_faulty_campaign(tmp_path):
    """Full campaign with injected deaths, pruning and requeues journals to
    a log that replays to the exact same service state."""
    path = str(tmp_path / "campaign.jsonl")
    srv = HopaasServer(storage=JournalStorage(path), lease_seconds=0.2,
                       seed=0)
    tok = srv.tokens.issue("c")
    run_campaign(
        _objective,
        study_spec=dict(name="wal",
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"},
                        pruner={"name": "median", "n_warmup_steps": 1}),
        transport_factory=lambda: DirectTransport(srv),
        token=tok, n_workers=6, n_trials=36, failure_rate=0.2, seed=11)
    time.sleep(0.25)
    srv.sweep_expired()                   # requeues the orphaned params
    cl = Client(DirectTransport(srv), tok)
    key = srv.storage.studies()[0].key
    waiting_before = []
    while True:
        item = srv.storage.pop_waiting(key)
        if item is None:
            break
        waiting_before.append(item)
    # capture *after* the drain: the study resource carries the shard's
    # data_version, so the comparison below is an exact-state equality —
    # any mutation (including the journaled pops above) must replay
    before = cl.studies()
    srv.storage.close()

    srv2 = HopaasServer(storage=JournalStorage(path), seed=0)
    cl2 = Client(DirectTransport(srv2), srv2.tokens.issue("c"))
    assert cl2.studies() == before
    # requeue queue state replays too (pops above were journaled)
    waiting_after = []
    while True:
        item = srv2.storage.pop_waiting(key)
        if item is None:
            break
        waiting_after.append(item)
    assert waiting_after == []
    # the restarted service keeps serving the study
    study = ClientStudy(name="wal", client=cl2,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"},
                        pruner={"name": "median", "n_warmup_steps": 1})
    with study.trial() as t:
        t.loss = abs(t.x)
    (s,) = [x for x in cl2.studies() if x["name"] == "wal"]
    assert s["n_trials"] == before[0]["n_trials"] + 1
    srv2.storage.close()
