"""Batched ask/tell wire protocol: one round trip suggests/finalizes k
trials, with the same accounting invariants as the sequential path."""
import threading

import pytest

from repro.core import (Client, ClientStudy, DirectTransport, HopaasServer,
                        HttpServiceRunner, HttpTransport, InMemoryStorage,
                        TokenManager, run_campaign, suggestions)
from repro.core.types import TrialState


@pytest.fixture()
def server():
    return HopaasServer(seed=0)


@pytest.fixture()
def client(server):
    return Client(DirectTransport(server), server.tokens.issue("tester"))


def make_study(client, name="b", sampler=None):
    return ClientStudy(
        name=name,
        properties={"x": suggestions.uniform(0.0, 1.0),
                    "n": suggestions.int(1, 9)},
        sampler=sampler or {"name": "random"}, client=client)


def test_ask_batch_returns_distinct_trials(client):
    study = make_study(client)
    trials = study.ask_batch(6)
    assert len(trials) == 6
    assert len({t.uid for t in trials}) == 6
    assert [t.id for t in trials] == list(range(6))
    for t in trials:
        assert 0.0 <= t.x <= 1.0 and 1 <= t.n <= 9


def test_ask_batch_advances_index_based_samplers(client):
    """Grid/Halton must not hand the same lattice point to every worker in
    the batch (the base suggest_batch extends the history between draws)."""
    study = ClientStudy(name="grid-batch", client=client,
                        properties={"x": suggestions.uniform(0.0, 1.0)},
                        sampler={"name": "grid", "points_per_dim": 5})
    xs = [t.x for t in study.ask_batch(5)]
    assert len(set(xs)) == 5


def test_tell_batch_finalizes_all(server, client):
    study = make_study(client)
    trials = study.ask_batch(4)
    results = study.tell_batch([(t, float(i)) for i, t in enumerate(trials)])
    assert [r["status"] for r in results] == [200] * 4
    for i, t in enumerate(trials):
        stored = server.storage.get_trial(t.uid)
        assert stored.state == TrialState.COMPLETED and stored.value == float(i)


def test_tell_batch_partial_conflict(server, client):
    """An already-finalized trial yields a per-item 409; the rest of the
    batch still lands."""
    study = make_study(client)
    t1, t2 = study.ask_batch(2)
    study.tell(t1, value=0.1)
    results = study.tell_batch([(t1, 0.2), (t2, 0.3)])
    assert results[0]["status"] == 409
    assert results[1]["status"] == 200
    assert server.storage.get_trial(t1.uid).value == 0.1
    assert server.storage.get_trial(t2.uid).value == 0.3


def test_tpe_batch_suggests_after_startup(server, client):
    """Past startup, ask_batch flows through the vectorized TPE top-k path."""
    study = make_study(client, sampler={"name": "tpe", "n_startup_trials": 4})
    for i in range(6):
        t = study.ask()
        study.tell(t, value=(t.x - 0.5) ** 2)
    batch = study.ask_batch(5)
    assert len({t.uid for t in batch}) == 5
    for t in batch:
        assert 0.0 <= t.x <= 1.0
    study.tell_batch([(t, (t.x - 0.5) ** 2) for t in batch])
    (s,) = [x for x in client.studies() if x["name"] == "b"]
    assert s["n_completed"] == 11


def test_batch_concurrent_workers_unique_trials(server):
    """8 concurrent batch workers over 4 studies: every suggested uid is
    unique and per-study accounting closes."""
    tok = server.tokens.issue("t")
    uids, lock = [], threading.Lock()

    def go(widx):
        cl = Client(DirectTransport(server), tok, worker_id=f"w{widx}")
        study = make_study(cl, name=f"cc-{widx % 4}")
        for _ in range(3):
            trials = study.ask_batch(4)
            with lock:
                uids.extend(t.uid for t in trials)
            study.tell_batch([(t, t.x) for t in trials])

    threads = [threading.Thread(target=go, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(uids) == len(set(uids)) == 8 * 3 * 4
    for study in server.storage.studies():
        counts = server.storage.counts(study.key)
        assert counts[TrialState.COMPLETED] == len(study.trials) == 24


def _objective(params, report):
    val = (params["x"] - 0.6) ** 2
    for step in range(3):
        if report(step, val + (3 - step) * 0.1):
            return val
    return val


def test_batch_campaign_accounting_matches_sequential():
    """run_campaign(batch_size=k) completes with the same trial accounting
    invariant (n_trials == completed + pruned + failed) as batch_size=1."""
    outcomes = {}
    for batch_size in (1, 4):
        srv = HopaasServer(seed=0)
        tok = srv.tokens.issue("c")
        res = run_campaign(
            _objective,
            study_spec=dict(name="bc",
                            properties={"x": suggestions.uniform(0, 1)},
                            sampler={"name": "tpe", "n_startup_trials": 6},
                            pruner={"name": "median", "n_warmup_steps": 1}),
            transport_factory=lambda srv=srv: DirectTransport(srv),
            token=tok, n_workers=4, n_trials=32, batch_size=batch_size,
            seed=7)
        assert res.n_trials == 32
        assert res.n_completed + res.n_pruned + res.n_failed == 32
        outcomes[batch_size] = res
    assert outcomes[4].best_value is not None


def test_batch_campaign_over_http_wire():
    storage, tokens = InMemoryStorage(), TokenManager()
    workers = [HopaasServer(storage=storage, tokens=tokens, seed=i)
               for i in range(2)]
    runner = HttpServiceRunner(workers).start()
    try:
        res = run_campaign(
            _objective,
            study_spec=dict(name="http-batch",
                            properties={"x": suggestions.uniform(0, 1)},
                            sampler={"name": "random"}),
            transport_factory=lambda: HttpTransport(runner.host, runner.port),
            token=tokens.issue("c"), n_workers=4, n_trials=24, batch_size=3,
            seed=1)
    finally:
        runner.stop()
    assert res.n_trials == 24
    assert res.n_completed + res.n_pruned + res.n_failed == 24
