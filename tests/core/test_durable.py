"""Durable storage engine: snapshots, segmented WAL, group-commit fsync,
compaction, and crash-recovery hardening (paper sec. 3 PostgreSQL role)."""
import gc
import json
import os
import threading
import time

import pytest

from repro.core import (Client, ClientStudy, CorruptJournalError,
                        DirectTransport, DurableStorage, HopaasServer,
                        suggestions)


def _drive(server, n=10, name="d", prune=True):
    cl = Client(DirectTransport(server), server.tokens.issue("t"))
    study = ClientStudy(name=name, client=cl,
                        properties={"x": suggestions.uniform(-1, 1)},
                        sampler={"name": "random"},
                        pruner=({"name": "median", "n_startup_trials": 3}
                                if prune else {"name": "none"}))
    for _ in range(n):
        with study.trial() as t:
            for s in range(3):
                if t.should_prune(s, abs(t.x) + (3 - s) * 0.1):
                    break
            t.loss = abs(t.x)
    return cl, study


def _segments(root):
    return sorted(f for f in os.listdir(root) if f.startswith("wal-"))


def _snapshots(root):
    return sorted(f for f in os.listdir(root) if f.startswith("snapshot-"))


# --------------------------------------------------------------------------- #
# recovery = snapshot + tail, digest-identical
# --------------------------------------------------------------------------- #
def test_restart_digest_identical(tmp_path):
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="always", auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    cl, _ = _drive(srv, n=12)
    before = cl.studies()
    digest = st.state_digest()
    st.close()

    st2 = DurableStorage(root, fsync="off")
    assert st2.state_digest() == digest
    srv2 = HopaasServer(storage=st2, seed=0)
    cl2 = Client(DirectTransport(srv2), srv2.tokens.issue("t"))
    assert cl2.studies() == before
    st2.close()


def test_rotation_compaction_and_tail_replay(tmp_path):
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="off", segment_bytes=1500,
                        auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    _drive(srv, n=20)
    assert len(_segments(root)) > 2          # rotation happened
    digest = st.state_digest()
    total_records = st.storage_stats()["wal_records"]

    folded = st.compact()
    assert folded >= 2
    assert len(_segments(root)) == 1         # only the active segment left
    assert len(_snapshots(root)) == 1
    assert st.state_digest() == digest       # compaction is read-only
    st.close()

    st2 = DurableStorage(root, fsync="off")
    assert st2.state_digest() == digest
    # recovery is snapshot + tail: only the unfolded tail is replayed
    rec = st2.last_recovery
    assert rec["snapshot_covers"] > 0
    assert rec["records_replayed"] < total_records
    st2.close()


def test_background_compactor_folds_sealed_segments(tmp_path):
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="off", segment_bytes=1200,
                        auto_compact=True)
    srv = HopaasServer(storage=st, seed=0)
    _drive(srv, n=25)
    digest = st.state_digest()
    # wait for the background compactor to catch up with the seals
    deadline = 100
    while st.storage_stats()["sealed_segments"] > 0 and deadline:
        time.sleep(0.05)
        deadline -= 1
    stats = st.storage_stats()
    assert stats["compactions"] >= 1
    assert stats["sealed_segments"] == 0
    assert st.state_digest() == digest
    st.close()


def test_crash_without_close_recovers(tmp_path):
    """Abandoning the store (no close(), like a SIGKILL) loses nothing in
    fsync=always mode; the restart digest matches exactly."""
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="always", segment_bytes=2000,
                        auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    cl, study = _drive(srv, n=15)
    digest = st.state_digest()
    best = [s for s in cl.studies() if s["name"] == "d"][0]["best_value"]
    # crash: no close().  Drop *every* reference — a dead process holds
    # none, and the kernel releases its WAL directory flock with it.
    del st, srv, cl, study
    gc.collect()          # break server<->context cycles; close the lock fd

    st2 = DurableStorage(root, fsync="off")
    assert st2.state_digest() == digest
    srv2 = HopaasServer(storage=st2, seed=0)
    cl2 = Client(DirectTransport(srv2), srv2.tokens.issue("t"))
    assert [s for s in cl2.studies()
            if s["name"] == "d"][0]["best_value"] == best
    st2.close()


def test_crash_restart_mid_campaign_resumes(tmp_path):
    """The satellite scenario: crash with running leases, queued requeues
    and intermediate reports in flight; restart must be digest-identical
    and the campaign must resume to the same best trial."""
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="always", auto_compact=False)
    srv = HopaasServer(storage=st, seed=0, lease_seconds=30.0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="camp", client=cl,
                        properties={"x": suggestions.uniform(-1, 1)},
                        sampler={"name": "random"})
    # completed trials with intermediate reports
    for _ in range(6):
        with study.trial() as t:
            t.should_prune(0, abs(t.x) + 1.0)
            t.loss = abs(t.x)
    # live leases + an intermediate report at crash time
    live = study.ask_batch(2)
    live[0].should_prune(1, 0.7)
    # a worker dies mid-trial: lease lapses, the sweeper requeues its
    # params — the waiting queue is non-empty when the crash hits
    dead = study.ask()
    st.update_trial(dead.uid, lease_deadline=time.time() - 1.0)
    srv.sweep_expired()
    digest = st.state_digest()
    dead_params = dead.params
    # crash mid-campaign: every reference gone, flock released with the
    # process
    del st, srv, cl, study, live, dead, t
    gc.collect()

    st2 = DurableStorage(root, fsync="always", auto_compact=False)
    assert st2.state_digest() == digest          # leases, queue, reports...
    srv2 = HopaasServer(storage=st2, seed=0, lease_seconds=60.0)
    cl2 = Client(DirectTransport(srv2), srv2.tokens.issue("t"))
    study2 = ClientStudy(name="camp", client=cl2,
                         properties={"x": suggestions.uniform(-1, 1)},
                         sampler={"name": "random"})
    # the requeued params of the dead worker are served first
    revived = study2.ask()
    assert revived.params == dead_params
    study2.tell(revived, value=abs(revived.params["x"]))
    resource = [s for s in cl2.studies() if s["name"] == "camp"][0]
    expected_best = min(float(t["value"]) for t in cl2.iter_trials(
        study2.study_key, state="completed"))
    assert resource["best_value"] == pytest.approx(expected_best)
    st2.close()


# --------------------------------------------------------------------------- #
# torn tails + corruption
# --------------------------------------------------------------------------- #
def test_torn_tail_in_active_segment_truncated(tmp_path):
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="always", auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    _drive(srv, n=6)
    digest = st.state_digest()
    st.close()

    active = os.path.join(root, _segments(root)[-1])
    with open(active, "ab") as f:               # crash mid-append
        f.write(b'{"op": "add_trial", "trial": {"trial_id"')
    st2 = DurableStorage(root, fsync="off", auto_compact=False)
    assert st2.last_recovery["torn_tail"] is True
    assert st2.state_digest() == digest          # the torn record is gone
    st2.close()
    # the repaired file no longer carries the torn bytes
    with open(active, "rb") as f:
        assert not f.read().rstrip().endswith(b'"trial_id')


def test_corruption_mid_segment_raises(tmp_path):
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="always", auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    _drive(srv, n=6)
    st.close()

    active = os.path.join(root, _segments(root)[-1])
    lines = open(active, "rb").read().splitlines(keepends=True)
    lines[1] = b'{"op": "add_trial", "tri\n'    # corrupt a middle record
    with open(active, "wb") as f:
        f.writelines(lines)
    with pytest.raises(CorruptJournalError):
        DurableStorage(root, fsync="off")


# --------------------------------------------------------------------------- #
# fsync modes + group commit
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("mode", ["always", "group", "off"])
def test_fsync_modes_roundtrip(tmp_path, mode):
    root = str(tmp_path / mode)
    st = DurableStorage(root, fsync=mode, auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    _drive(srv, n=5, prune=False)
    digest = st.state_digest()
    stats = st.storage_stats()
    assert stats["fsync"] == mode
    if mode == "always":
        assert stats["fsyncs"] >= 1
    if mode == "off":
        assert stats["fsyncs"] == 0
    st.close()
    st2 = DurableStorage(root, fsync="off")
    assert st2.state_digest() == digest
    st2.close()


def test_group_commit_batches_fsyncs(tmp_path):
    """In group mode many mutations share one fsync per commit window —
    far fewer fsyncs than records."""
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="group", group_interval=0.05,
                        auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    _drive(srv, n=20, prune=False)
    stats = st.storage_stats()
    assert stats["wal_records"] >= 40
    assert stats["fsyncs"] < stats["wal_records"] / 4
    st.close()
    # close() makes the tail durable regardless of the window
    assert st.storage_stats()["fsyncs"] >= 1


def test_concurrent_writers_group_commit(tmp_path):
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="always", auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    tok = srv.tokens.issue("t")

    def go():
        cl = Client(DirectTransport(srv), tok)
        study = ClientStudy(name="cc", client=cl,
                            properties={"x": suggestions.uniform(0, 1)},
                            sampler={"name": "random"})
        for _ in range(5):
            with study.trial() as t:
                t.loss = t.x

    threads = [threading.Thread(target=go) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    digest = st.state_digest()
    stats = st.storage_stats()
    # create + 40x(add + update); the tell's idempotency-window note
    # rides inside the finalize record, not as a record of its own
    assert stats["wal_records"] == 1 + 80
    st.close()
    st2 = DurableStorage(root, fsync="off")
    assert st2.state_digest() == digest
    study = next(iter(st2.studies()))
    assert len(study.trials) == 40
    st2.close()


# --------------------------------------------------------------------------- #
# stats surfaces
# --------------------------------------------------------------------------- #
def test_storage_stats_on_v2_version_and_study(tmp_path):
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="group", auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    cl, study = _drive(srv, n=4, prune=False)

    status, payload, _ = srv.handle_request("GET", "/api/v2/version")
    assert status == 200
    storage = payload["storage"]
    assert storage["backend"] == "durable"
    assert storage["fsync"] == "group"
    assert storage["wal_records"] > 0
    assert "last_recovery" in storage and "snapshot_covers" in storage

    resource = cl.study(study.study_key)
    assert resource["data_version"] == st.data_version(study.study_key)

    # the v1 version payload stays byte-frozen
    status, payload = srv.handle("GET", "/api/version")
    assert status == 200 and set(payload) == {"version"}
    st.close()


def test_memory_backend_stats():
    srv = HopaasServer(seed=0)
    status, payload, _ = srv.handle_request("GET", "/api/v2/version")
    assert status == 200
    assert payload["storage"]["backend"] in ("memory", "durable")


def test_snapshot_preserves_waiting_queue_and_completion_order(tmp_path):
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="off", segment_bytes=400,
                        auto_compact=False)
    srv = HopaasServer(storage=st, seed=0, lease_seconds=0.01)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="q", client=cl,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"})
    # out-of-order completion: trial 1 completes before trial 0
    a, b = study.ask(), study.ask()
    study.tell(b, value=0.5)
    study.tell(a, value=0.4)
    # a lapsed lease leaves params in the waiting queue
    study.ask()
    time.sleep(0.02)
    srv.sweep_expired()
    key = study.study_key
    assert st.compact(min_segments=1) >= 1       # fold into a snapshot
    st.close()

    st2 = DurableStorage(root, fsync="off")
    shard = st2._shard(key)
    assert [u.rsplit(":", 1)[1] for u in shard.completed_log] == ["1", "0"]
    assert len(shard.waiting) == 1               # the requeued params
    assert st2.best_trial(key).value == 0.4
    st2.close()


def test_compact_refuses_after_close(tmp_path):
    """A straggler compaction must never mutate a directory after close()
    returned — another DurableStorage may have re-opened it."""
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="off", segment_bytes=400,
                        auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    _drive(srv, n=4, prune=False)
    st.close()
    files = sorted(os.listdir(root))
    assert st.compact(min_segments=1) == 0       # refused, not raced
    assert sorted(os.listdir(root)) == files     # directory untouched


def test_snapshot_is_strict_json(tmp_path):
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="off", segment_bytes=400,
                        auto_compact=False)
    srv = HopaasServer(storage=st, seed=0)
    _drive(srv, n=3, prune=False)
    assert st.compact(min_segments=1) >= 1
    snap = os.path.join(root, _snapshots(root)[0])
    # parse with a strict JSON reader: NaN/Infinity would blow up here
    json.loads(open(snap).read(),
               parse_constant=lambda c: (_ for _ in ()).throw(
                   ValueError(f"non-strict constant {c}")))
    st.close()
