"""Replicated durable shards (PR 7): WAL-segment shipping, leader
leases, digest-verified automatic failover, and the fault-injection
harness that proves them.

The in-process tests exercise the hub/client protocol, corruption
rejection, the idempotency window, leases, and the health surface.  The
``chaos``-marked tests run the real multi-process fabric and kill (or
wedge) the leader under a live campaign — the acceptance scenario: zero
lost acked tells, no double counts, bounded unavailability, and a
fenced ex-leader."""
import json
import os
import signal
import subprocess
import sys
import threading
import time

import pytest

from repro.core import (Client, ClientStudy, DirectTransport,
                        DurableStorage, HopaasServer, HttpTransport,
                        InMemoryStorage, ReplicationClient, ReplicationHub,
                        RetryPolicy, ShardFabric, TokenManager,
                        recover_dir_state, reconcile_with, suggestions)
from repro.core import faults
from repro.core.durable import _describe_lock_meta
from repro.core.fabric import FabricWorkerServer
from repro.core.storage import _DEDUP_WINDOW

_SPACE = {"x": suggestions.uniform(-1.0, 1.0)}
_PATIENT = RetryPolicy(max_attempts=10, base_delay=0.1, max_delay=1.0)


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.install({})
    yield
    faults.install({})


def _drive(server, n=8, name="rep"):
    cl = Client(DirectTransport(server), server.tokens.issue("t"))
    study = ClientStudy(name=name, client=cl, properties=dict(_SPACE),
                        sampler={"name": "random"})
    for _ in range(n):
        t = study.ask()
        study.tell(t, value=abs(t.x))
    return cl, study


def _leader(tmp_path, name="leader", **kw):
    kw.setdefault("fsync", "off")
    kw.setdefault("auto_compact", False)
    return DurableStorage(str(tmp_path / name), **kw)


# --------------------------------------------------------------------- #
# hub <-> client protocol
# --------------------------------------------------------------------- #
def test_follower_replays_stream_to_identical_digest(tmp_path):
    storage = _leader(tmp_path)
    hub = ReplicationHub(storage)
    storage.attach_replicator(hub)
    srv = HopaasServer(storage=storage, seed=0)
    _drive(srv, n=6)

    shadow = _leader(tmp_path, "follower")
    client = ReplicationClient(shadow, ("127.0.0.1", hub.port)).start()
    try:
        assert client.wait_connected()
        assert client.wait_position(hub.position())
        assert shadow.state_digest() == storage.state_digest()
        # records published after attach stream live, not via baseline
        _drive(srv, n=3, name="rep2")
        assert client.wait_position(hub.position())
        assert shadow.state_digest() == storage.state_digest()
        # hub-side ack bookkeeping is asynchronous wrt the client's
        # applied position — poll it down to zero
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            lag = hub.status()["followers"][0]
            if lag["lag_records"] == 0 and lag["lag_bytes"] == 0:
                break
            time.sleep(0.02)
        assert lag["lag_records"] == 0 and lag["lag_bytes"] == 0
    finally:
        client.stop()
        hub.stop()
        shadow.close()
        storage.close()


def test_idle_leader_ships_at_most_one_baseline(tmp_path):
    """An empty leader (stream position 0) serving a fresh follower
    (also at 0) must ship its empty baseline once and then block for
    traffic — regression: the cursor==0 re-baseline clause used to
    refire every loop iteration on an idle shard, busy-shipping empty
    baselines forever (found on a live idle fabric shard)."""
    storage = _leader(tmp_path)
    hub = ReplicationHub(storage)
    storage.attach_replicator(hub)
    shadow = _leader(tmp_path, "follower")
    client = ReplicationClient(shadow, ("127.0.0.1", hub.port)).start()
    try:
        assert client.wait_connected()
        time.sleep(0.5)     # the buggy loop ships thousands in this window
        assert hub.status()["baselines_shipped"] <= 1
        assert client.status()["baselines"] <= 1
        # the idle connection still streams once traffic arrives
        srv = HopaasServer(storage=storage, seed=0)
        _drive(srv, n=3)
        assert client.wait_position(hub.position())
        assert shadow.state_digest() == storage.state_digest()
    finally:
        client.stop()
        hub.stop()
        shadow.close()
        storage.close()


def test_follower_survives_restart_and_resyncs(tmp_path):
    """A new hub process (fresh session nonce) invalidates stream
    positions: the follower resets and takes a fresh baseline."""
    storage = _leader(tmp_path)
    hub = ReplicationHub(storage)
    storage.attach_replicator(hub)
    srv = HopaasServer(storage=storage, seed=0)
    _drive(srv, n=4)
    shadow = InMemoryStorage()
    client = ReplicationClient(shadow, ("127.0.0.1", hub.port)).start()
    try:
        assert client.wait_connected()
        assert client.wait_position(hub.position())
        hub.stop()
        # the just-closed follower connection can hold the port briefly
        deadline = time.monotonic() + 10.0
        while True:
            try:
                hub2 = ReplicationHub(storage, port=hub.port)
                break
            except OSError:
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.05)
        storage.attach_replicator(hub2)
        _drive(srv, n=2, name="after")
        # the client's stale position satisfies wait_position until it
        # has re-handshaken, so wait for the *new session* to catch up
        deadline = time.monotonic() + 15.0
        while time.monotonic() < deadline:
            st = client.status()
            if (st["session"] == hub2.session
                    and st["pos"] >= hub2.position()):
                break
            time.sleep(0.02)
        assert client.status()["session"] == hub2.session
        assert shadow.state_digest() == storage.state_digest()
        assert client.status()["resyncs"] >= 1
        hub2.stop()
    finally:
        client.stop()
        storage.close()


def test_semisync_acks_wait_for_a_follower(tmp_path):
    storage = _leader(tmp_path)
    hub = ReplicationHub(storage)
    # semisync with nobody listening degrades to async instantly
    storage.attach_replicator(hub, semisync=True)
    srv = HopaasServer(storage=storage, seed=0)
    _drive(srv, n=2)

    shadow = InMemoryStorage()
    client = ReplicationClient(shadow, ("127.0.0.1", hub.port)).start()
    try:
        assert client.wait_connected()
        _drive(srv, n=4, name="synced")
        # every acked write has been acknowledged by the follower: the
        # write path waited, so there is no residual lag to wait out
        st = hub.status()
        assert any(f["acked"] >= st["pos"] for f in st["followers"])
        assert st["semisync_degraded"] == 0
    finally:
        client.stop()
        hub.stop()
        storage.close()


# --------------------------------------------------------------------- #
# satellite: corrupt-in-flight shipping is rejected, never adopted
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("mangle", ["torn", "bitflip"])
def test_corrupt_shipped_payload_rejected_and_reshipped(tmp_path, mangle):
    storage = _leader(tmp_path)
    hub = ReplicationHub(storage)
    storage.attach_replicator(hub)
    srv = HopaasServer(storage=storage, seed=0)
    _drive(srv, n=5)

    shadow = InMemoryStorage()
    client = ReplicationClient(shadow, ("127.0.0.1", hub.port)).start()
    try:
        assert client.wait_position(hub.position(), timeout=15.0)
        # corrupt the next shipped record frame in flight: the follower
        # must reject it (short read / checksum) and re-request — the
        # mangled bytes are never adopted into the shadow store
        faults.install({"torn_ship": {"mode": "nth", "n": 1,
                                      "arg": mangle}}, seed=7)
        _drive(srv, n=4, name="after-fault")
        assert client.wait_position(hub.position(), timeout=15.0)
        assert shadow.state_digest() == storage.state_digest()
        st = client.status()
        if mangle == "bitflip":
            # same length, wrong bytes: caught by checksum before replay
            assert st["rejects"] >= 1
        assert faults.injector().stats()["fired"].get("torn_ship") == 1
        assert hub.status()["pos"] == st["pos"]
    finally:
        client.stop()
        hub.stop()
        storage.close()


def test_partitioned_follower_catches_up_after_heal(tmp_path):
    storage = _leader(tmp_path)
    hub = ReplicationHub(storage)
    storage.attach_replicator(hub)
    srv = HopaasServer(storage=storage, seed=0)
    _drive(srv, n=3)
    faults.install({"partition_follower": {"mode": "always"}}, seed=1)
    shadow = InMemoryStorage()
    client = ReplicationClient(shadow, ("127.0.0.1", hub.port),
                               retry_interval=0.01).start()
    try:
        time.sleep(0.2)
        assert not client.connected()
        assert client.position() == 0
        faults.install({})               # heal the partition
        assert client.wait_connected(timeout=10.0)
        assert client.wait_position(hub.position())
        assert shadow.state_digest() == storage.state_digest()
    finally:
        client.stop()
        hub.stop()
        storage.close()


# --------------------------------------------------------------------- #
# satellite: exactly-once tells (idempotency keys + dedup window)
# --------------------------------------------------------------------- #
def test_tell_idempotency_key_replays_original_result():
    srv = HopaasServer(seed=0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="idem", client=cl, properties=dict(_SPACE),
                        sampler={"name": "random"})
    t = study.ask()
    first = srv.op_tell(t.uid, 0.25, "completed", "key-1")
    again = srv.op_tell(t.uid, 999.0, "failed", "key-1")
    assert again == first                # replay, not a second finalize
    trial = srv.storage.get_trial(t.uid)
    assert trial.state.value == "completed" and trial.value == 0.25
    # a *different* key is a genuine duplicate finalize -> 409
    from repro.core.api import ApiError
    with pytest.raises(ApiError) as e:
        srv.op_tell(t.uid, 1.0, "completed", "key-2")
    assert e.value.status == 409


def test_dedup_window_is_bounded_fifo():
    storage = InMemoryStorage()
    study, _created = storage.get_or_create_study(_config("fifo"))
    key = study.key
    for i in range(_DEDUP_WINDOW + 8):
        storage.note_idempotency(key, f"k{i}", {"i": i})
    assert storage.idempotent_result(key, "k0") is None      # evicted
    assert storage.idempotent_result(
        key, f"k{_DEDUP_WINDOW + 7}") == {"i": _DEDUP_WINDOW + 7}


def _config(name):
    from repro.core.types import StudyConfig
    return StudyConfig(name=name, properties=dict(_SPACE),
                       sampler={"name": "random"})


def test_dedup_window_survives_recovery_and_replication(tmp_path):
    storage = _leader(tmp_path)
    hub = ReplicationHub(storage)
    storage.attach_replicator(hub)
    srv = HopaasServer(storage=storage, seed=0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="idem-d", client=cl, properties=dict(_SPACE),
                        sampler={"name": "random"})
    t = study.ask()
    first = srv.op_tell(t.uid, 0.5, "completed", "key-x")

    shadow = InMemoryStorage()
    client = ReplicationClient(shadow, ("127.0.0.1", hub.port)).start()
    try:
        assert client.wait_position(hub.position())
        # the follower replayed the idem record: a promoted leader gives
        # the same answer to the same retried tell
        assert shadow.idempotent_result(study.study_key, "key-x") == first
    finally:
        client.stop()
        hub.stop()
        storage.close()
    # and crash-recovery restores the window from the WAL
    recovered = DurableStorage(str(tmp_path / "leader"), fsync="off",
                               auto_compact=False)
    try:
        assert recovered.idempotent_result(study.study_key,
                                           "key-x") == first
    finally:
        recovered.close()


# --------------------------------------------------------------------- #
# satellite: health endpoint
# --------------------------------------------------------------------- #
def test_health_endpoint_reports_role_epoch_and_storage(tmp_path):
    storage = _leader(tmp_path, fsync="group")
    hub = ReplicationHub(storage)
    storage.attach_replicator(hub)
    srv = HopaasServer(storage=storage, seed=0)
    _drive(srv, n=2)
    try:
        status, payload, _ = DirectTransport(srv).request_full(
            "GET", "/api/v2/health")          # unauthenticated by design
        assert status == 200
        assert payload["status"] == "ok" and payload["role"] == "leader"
        assert payload["epoch"] == 0
        assert payload["storage"]["backend"] == "durable"
        assert payload["storage"]["wal_records"] > 0
        assert payload["replication"]["pos"] == hub.position()
    finally:
        hub.stop()
        storage.close()


# --------------------------------------------------------------------- #
# satellite: LOCK.meta names the holder (and calls out staleness)
# --------------------------------------------------------------------- #
def test_wal_lock_error_names_live_holder(tmp_path):
    from repro.core import WalDirectoryLockedError
    root = str(tmp_path / "store")
    st = DurableStorage(root, fsync="off", auto_compact=False)
    try:
        with pytest.raises(WalDirectoryLockedError) as e:
            DurableStorage(root, fsync="off")
        msg = str(e.value)
        assert "locked by another live process" in msg
        assert f"holder meta: pid {os.getpid()}" in msg
        assert "(live)" in msg
    finally:
        st.close()
    assert not os.path.exists(os.path.join(root, "LOCK.meta"))


def test_stale_lock_meta_from_dead_pid_reported_as_stale(tmp_path):
    # burn a pid that is certainly dead now
    proc = subprocess.Popen([sys.executable, "-c", "pass"])
    proc.wait()
    meta = tmp_path / "LOCK.meta"
    meta.write_text(json.dumps({"pid": proc.pid, "host": "testhost",
                                "started_at": time.time()}))
    desc = _describe_lock_meta(str(meta))
    assert f"pid {proc.pid}" in desc and "on testhost" in desc
    assert "stale: meta pid is dead" in desc


# --------------------------------------------------------------------- #
# promotion helpers + fencing (in-process)
# --------------------------------------------------------------------- #
def test_recover_dir_state_is_readonly_and_reconcile_verifies(tmp_path):
    storage = _leader(tmp_path, fsync="always")
    srv = HopaasServer(storage=storage, seed=0)
    _drive(srv, n=6)
    want = storage.state_digest()
    storage.close()

    before = sorted(os.listdir(tmp_path / "leader"))
    authority, meta = recover_dir_state(str(tmp_path / "leader"))
    assert authority.state_digest() == want
    assert meta["records_replayed"] > 0 and not meta["torn_tail"]
    assert sorted(os.listdir(tmp_path / "leader")) == before   # untouched

    follower = _leader(tmp_path, "f2")
    try:
        out = reconcile_with(follower, authority)
        assert out["digest_match"] and follower.state_digest() == want
        # idempotent: a caught-up store needs no drops or adopts
        again = reconcile_with(follower, authority)
        assert again == {"dropped": 0, "adopted": 0, "digest_match": True}
    finally:
        follower.close()


def test_fenced_worker_rejects_data_plane_but_answers_health():
    tokens = TokenManager("s")
    srv = HopaasServer(tokens=tokens, seed=0)
    worker = FabricWorkerServer(srv, worker_id=3)
    srv.health_hook = worker.health_extra
    auth = {"Authorization": f"Bearer {tokens.issue('ctl')}"}
    status, out, _ = worker.handle_request("POST", "/fabric/fence",
                                           {"epoch": 2}, auth)
    assert status == 200 and out["fenced"]
    # stale fence (not newer than the current epoch) is refused
    status, out, _ = worker.handle_request("POST", "/fabric/fence",
                                           {"epoch": 0}, auth)
    assert status == 409 and out["error"]["code"] == "stale_epoch"
    # data plane: retryable 409 shard_failover
    status, out, hdrs = worker.handle_request(
        "POST", "/api/v2/studies", {"name": "x",
                                    "properties": dict(_SPACE)}, auth)
    assert status == 409 and out["error"]["code"] == "shard_failover"
    assert "Retry-After" in hdrs
    # health stays observable on a fenced worker
    status, health, _ = worker.handle_request("GET", "/api/v2/health")
    assert status == 200 and health["status"] == "fenced"
    assert health["epoch"] == 0


def test_clock_skewed_lease_expires_immediately():
    faults.install({"lease_skew": {"mode": "always",
                                   "arg": -3600.0}}, seed=0)
    srv = HopaasServer(seed=0, lease_seconds=60.0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="skew", client=cl, properties=dict(_SPACE),
                        sampler={"name": "random"})
    study.ask()
    # the skewed clock stamped a lease already in the past
    assert srv.sweep_expired() >= 1


def test_crash_before_fsync_loses_nothing_that_was_acked(tmp_path):
    """A worker that dies *inside* the fsync window must still recover
    every write it acknowledged before the crash (the injection point
    kills the process right before the fsync syscall; acked writes from
    earlier batches are already on stable storage or in the page
    cache)."""
    root = str(tmp_path / "crashy")
    prog = (
        "import repro.core.faults as f\n"
        "f.load_from_env()\n"
        "from repro.core import HopaasServer, DurableStorage\n"
        "srv = HopaasServer(storage=DurableStorage(%r, fsync='always',"
        " auto_compact=False), seed=0)\n"
        "cfg = {'name': 'c', 'properties': {'x': {'type': 'uniform',"
        " 'low': 0, 'high': 1}}, 'sampler': {'name': 'random'}}\n"
        "_created, res = srv.op_create_study(cfg)\n"
        "key = res['key']\n"
        "for i in range(50):\n"
        "    (t,) = srv.op_ask(key, 'w', 1)\n"
        "    srv.op_tell(t['uid'], float(i), 'completed')\n"
        "    print(t['uid'], flush=True)\n"
    ) % root
    import repro.core
    src_dir = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(repro.core.__file__))))
    env = dict(os.environ, REPRO_FAULTS=json.dumps(
        {"faults": {"crash_before_fsync": {"mode": "nth", "n": 40}}}))
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", prog], env=env,
                          capture_output=True, text=True, timeout=60)
    assert proc.returncode == 137, proc.stderr   # died at the injection
    acked = [ln for ln in proc.stdout.splitlines() if ln.strip()]
    assert acked                                  # made progress first
    store, meta = recover_dir_state(root)
    have = {t.uid for s in store.studies() for t in s.trials}
    assert set(acked) <= have, sorted(set(acked) - have)


# --------------------------------------------------------------------- #
# chaos: the acceptance scenarios on the real fabric
# --------------------------------------------------------------------- #
def _fab_client(fab):
    tok = fab.issue_token("t")
    return Client(HttpTransport(fab.host, fab.port), tok,
                  retry=_PATIENT), tok


def _fab_study(cl, name):
    return ClientStudy(name=name, client=cl, properties=dict(_SPACE),
                       sampler={"name": "random"})


@pytest.mark.chaos
def test_kill_the_leader_mid_campaign_loses_no_acked_tell():
    """The acceptance drill: SIGKILL the owning leader while a threaded
    campaign asks/tells through the router.  The monitor must promote
    the most-caught-up follower with a digest matching the dead
    leader's WAL, no acked tell may vanish, no completion may double
    count, and the availability gap must stay under 5 s."""
    fab = ShardFabric(workers=2, replicas=1, replication="semisync",
                      fsync="always", respawn_poll=0.1,
                      lease_seconds=5.0).start()
    try:
        cl, _tok = _fab_client(fab)
        study = _fab_study(cl, "killdrill")
        key = study._ensure_key()
        wid = fab.owner_of(key)

        stop = threading.Event()
        told: list[str] = []
        done_at: list[float] = []
        errors: list[str] = []
        lock = threading.Lock()

        def campaign():
            local = _fab_study(_fab_client(fab)[0], "killdrill")
            while not stop.is_set():
                try:
                    t = local.ask()
                    local.tell(t, value=abs(t.x))
                    with lock:
                        told.append(t.uid)
                        done_at.append(time.monotonic())
                except Exception as e:            # pragma: no cover
                    errors.append(repr(e))
                    return

        threads = [threading.Thread(target=campaign) for _ in range(3)]
        for th in threads:
            th.start()
        time.sleep(0.5)                          # campaign in full flight
        old_pid = fab._workers[wid].pid
        killed_at = time.monotonic()
        fab.kill_worker(wid, sig=signal.SIGKILL)
        fab.wait_respawn(wid, old_pid, timeout=30)
        time.sleep(1.0)                          # keep telling post-failover
        stop.set()
        for th in threads:
            th.join(timeout=30)
        assert not errors, errors

        event = [e for e in fab.events if e["event"] == "failover"][-1]
        assert event["worker"] == wid and event["epoch"] >= 1
        # promoted state matches the dead leader's WAL exactly
        assert event["digest_match"] is True
        assert fab.failovers >= 1

        # bounded unavailability: the first acked tell after the kill
        # landed within the 5 s budget
        after = [t for t in done_at if t > killed_at]
        assert after, "campaign never recovered after the kill"
        assert min(after) - killed_at < 5.0

        # zero lost acked tells, zero double counts
        completed = {t["uid"] for t in cl.iter_trials(key,
                                                      state="completed")}
        assert set(told) <= completed
        assert len(told) == len(set(told))
        assert cl.study(key)["n_completed"] == len(completed)
    finally:
        fab.stop()


@pytest.mark.chaos
def test_deposed_leader_is_fenced_on_return():
    """SIGSTOP wedges the leader (hung, not dead): the monitor promotes
    a follower, and when the old leader resumes it gets fenced — its
    data plane answers a retryable 409 with the stale epoch, so it can
    never ack a write the promoted leader doesn't have."""
    fab = ShardFabric(workers=2, replicas=1, replication="semisync",
                      fsync="always", respawn_poll=0.1,
                      hang_grace=0.8).start()
    try:
        cl, tok = _fab_client(fab)
        study = _fab_study(cl, "fence")
        key = study._ensure_key()
        wid = fab.owner_of(key)
        for _ in range(5):
            t = study.ask()
            study.tell(t, value=abs(t.x))

        old = fab._workers[wid]
        old_pid, old_port = old.pid, old.port
        fab.kill_worker(wid, sig=signal.SIGSTOP)
        wp = fab.wait_respawn(wid, old_pid, timeout=30)
        assert wp.pid != old_pid
        # service continues through the promoted follower
        t = study.ask()
        study.tell(t, value=abs(t.x))

        os.kill(old_pid, signal.SIGCONT)
        deadline = time.monotonic() + 15.0
        while time.monotonic() < deadline:
            if any(e["event"] == "fence" for e in fab.events):
                break
            time.sleep(0.1)
        fence = [e for e in fab.events if e["event"] == "fence"]
        assert fence and fence[-1]["epoch"] >= 1

        # a client still pointed at the deposed leader gets the
        # retryable failover signal, never a stale ack
        raw = HttpTransport(fab.host, old_port, timeout=5.0)
        status, payload, _ = raw.request_full(
            "POST", f"/api/v2/studies/{key}/trials:ask",
            {"worker_id": "t"},
            headers={"Authorization": f"Bearer {tok}"})
        assert status == 409
        assert payload["error"]["code"] == "shard_failover"
        assert "fenced by epoch" in payload["error"]["message"]

        # fleet health shows exactly one leader for this wid, new epoch
        entries = [w for w in fab.health()["workers"]
                   if w["worker"] == wid and "error" not in w]
        roles = [w["role"] for w in entries]
        assert roles.count("leader") == 1
        assert max(w["epoch"] for w in entries) >= 1
    finally:
        fab.stop()


@pytest.mark.chaos
def test_fabric_health_reports_followers_and_lag():
    fab = ShardFabric(workers=2, replicas=1, fsync="off",
                      respawn_poll=0.2).start()
    try:
        cl, _tok = _fab_client(fab)
        study = _fab_study(cl, "lag")
        study._ensure_key()
        for _ in range(4):
            t = study.ask()
            study.tell(t, value=abs(t.x))
        health = fab.health()
        assert health["replicas"] == 1
        roles = [w.get("role") for w in health["workers"]]
        assert roles.count("leader") == 2 and roles.count("follower") == 2
        # per-worker health through the data plane answers from any role
        follower = next(w for w in health["workers"]
                        if w.get("role") == "follower")
        host, port = follower["endpoint"]
        status, payload, _ = HttpTransport(host, port).request_full(
            "GET", "/api/v2/health")
        assert status == 200 and payload["status"] == "follower"
        assert payload["replication"]["client"]["connected"] is True
    finally:
        fab.stop()


@pytest.mark.chaos
def test_cold_start_adopts_highest_epoch_replica_root(tmp_path):
    """ROADMAP item 6 regression: after an in-flight failover (follower
    promoted, epoch bumped, writes landing in ``worker-N-replica-M/``),
    a full-fleet SIGKILL + restart on the same journal root must boot
    the shard from the highest journaled epoch — every acked
    post-failover tell is served by the reborn fleet, digest-verified,
    not silently dropped by an epoch-0 boot from ``worker-N/``."""
    root = str(tmp_path)
    fab = ShardFabric(workers=2, replicas=1, replication="semisync",
                      fsync="always", respawn_poll=0.1, root=root).start()
    told: list[str] = []
    try:
        cl, _tok = _fab_client(fab)
        study = _fab_study(cl, "coldstart")
        key = study._ensure_key()
        wid = fab.owner_of(key)
        for _ in range(4):
            t = study.ask()
            study.tell(t, value=abs(t.x))
            told.append(t.uid)

        # in-flight failover: the follower takes over at a bumped epoch
        old_pid = fab._workers[wid].pid
        fab.kill_worker(wid, sig=signal.SIGKILL)
        fab.wait_respawn(wid, old_pid, timeout=30)
        assert any(e["event"] == "failover" for e in fab.events)
        promoted_epoch = fab._workers[wid].epoch
        assert promoted_epoch >= 1
        # acked post-failover tells: these land in a replica-M root
        for _ in range(4):
            t = study.ask()
            study.tell(t, value=abs(t.x))
            told.append(t.uid)
    finally:
        # full-fleet kill: no graceful drain, the page cache + fsynced
        # WALs are all that survives
        fab._stop_event.set()
        if fab._monitor is not None:
            fab._monitor.join(timeout=10.0)
        with fab._fleet_lock:
            procs = [wp.proc for wp in fab._workers.values()]
            procs += [fp.proc for fols in fab._followers.values()
                      for fp in fols]
            procs += [wp.proc for wp in fab._deposed]
        for proc in procs:
            if proc.poll() is None:
                proc.kill()
        fab.stop()

    fab2 = ShardFabric(workers=2, replicas=1, replication="semisync",
                       fsync="always", respawn_poll=0.1, root=root).start()
    try:
        adopts = [e for e in fab2.events if e["event"] == "cold_start_adopt"]
        assert adopts, "cold start ignored the higher-epoch replica root"
        event = next(e for e in adopts if e["worker"] == wid)
        assert event["epoch"] > promoted_epoch
        assert event["digest_match"] is True
        assert fab2._workers[wid].epoch == event["epoch"]

        # every acked tell — before and after the in-flight failover —
        # is served by the reborn fleet
        cl2, _tok2 = _fab_client(fab2)
        completed = {t["uid"] for t in cl2.iter_trials(key,
                                                       state="completed")}
        assert set(told) <= completed
        assert cl2.study(key)["n_completed"] == len(completed)

        # the fleet keeps working at the adopted epoch (new followers
        # get fresh replica roots, no collision with the adopted one)
        study2 = _fab_study(cl2, "coldstart")
        t = study2.ask()
        study2.tell(t, value=abs(t.x))
    finally:
        fab2.stop()
