"""Fault tolerance: leases, straggler requeue, elastic campaigns, HTTP wire."""
import time

import pytest

from repro.core import (Client, ClientStudy, DirectTransport, HopaasServer,
                        HttpServiceRunner, HttpTransport, InMemoryStorage,
                        TokenManager, run_campaign, suggestions)
from repro.core.types import TrialState


def test_lease_expiry_requeues_params():
    srv = HopaasServer(lease_seconds=0.05, seed=0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="lease", client=cl,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"})
    dead = study.ask()                  # worker "dies": never tells
    time.sleep(0.08)
    srv.sweep_expired()
    stored = srv.storage.get_trial(dead.uid)
    assert stored.state == TrialState.FAILED

    revived = study.ask()               # next ask serves the requeued params
    assert revived.params == dead.params
    study.tell(revived, value=0.5)
    stored2 = srv.storage.get_trial(revived.uid)
    assert stored2.retries == 1 and stored2.state == TrialState.COMPLETED


def test_requeue_bounded_by_max_retries():
    srv = HopaasServer(lease_seconds=0.01, max_retries=2, seed=0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="retry", client=cl,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"})
    t = study.ask()
    params0 = t.params
    seen = 1
    for _ in range(6):
        time.sleep(0.02)
        srv.sweep_expired()
        t = study.ask()
        if t.params == params0:
            seen += 1
    assert seen <= 3                    # original + at most 2 retries


def test_heartbeat_renews_lease():
    srv = HopaasServer(lease_seconds=0.15, seed=0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="hb", client=cl,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"})
    t = study.ask()
    for step in range(4):               # keep reporting -> stays alive
        time.sleep(0.05)
        t.should_prune(step, 1.0)
        srv.sweep_expired()
    stored = srv.storage.get_trial(t.uid)
    assert stored.state == TrialState.RUNNING
    study.tell(t, value=1.0)


def quad_objective(params, report):
    val = (params["x"] - 1.0) ** 2 + (params["y"] + 2.0) ** 2
    for step in range(4):
        if report(step, val + (4 - step) * 0.1):
            break
    return val


def test_campaign_with_worker_failures():
    """Sec. 4-style campaign with injected worker deaths: the study still
    completes its budget and converges; failed trials are requeued."""
    srv = HopaasServer(lease_seconds=1.0, seed=0)
    tok = srv.tokens.issue("campaign")
    res = run_campaign(
        quad_objective,
        study_spec=dict(name="ft", direction="minimize",
                        properties={"x": suggestions.uniform(-5, 5),
                                    "y": suggestions.uniform(-5, 5)},
                        sampler={"name": "tpe", "n_startup_trials": 8},
                        pruner={"name": "none"}),
        transport_factory=lambda: DirectTransport(srv),
        token=tok, n_workers=8, n_trials=48, failure_rate=0.15, seed=3)
    # dead workers' leases expire; the sweeper declares them failed
    time.sleep(1.05)
    srv.sweep_expired()
    study = next(iter(srv.storage.studies()))
    states = [t.state for t in study.trials]
    assert states.count(TrialState.FAILED) > 0           # failures happened
    assert states.count(TrialState.RUNNING) == 0         # nothing leaked
    done = states.count(TrialState.COMPLETED)
    assert done + states.count(TrialState.FAILED) + states.count(
        TrialState.PRUNED) == len(states)                # full accounting
    assert done >= 30                                    # budget mostly met
    # mean objective under the prior is ~21; the campaign must do far better
    # despite the failures (asks from concurrent workers see stale tells, so
    # this is deliberately looser than the serial-sampler tests)
    assert res.best_value < 5.0


def test_elastic_late_joining_workers():
    srv = HopaasServer(seed=0)
    tok = srv.tokens.issue("campaign")

    def slow_objective(params, report):       # non-zero work so workers overlap
        time.sleep(0.02)
        return quad_objective(params, report)

    res = run_campaign(
        slow_objective,
        study_spec=dict(name="elastic", direction="minimize",
                        properties={"x": suggestions.uniform(-5, 5),
                                    "y": suggestions.uniform(-5, 5)},
                        sampler={"name": "random"}, pruner={"name": "none"}),
        transport_factory=lambda: DirectTransport(srv),
        token=tok, n_workers=6, n_trials=24, stagger_seconds=0.01, seed=0)
    assert res.n_completed == 24
    assert len(res.trials_per_worker) >= 3   # late joiners still got work


@pytest.fixture()
def http_service():
    storage, tokens = InMemoryStorage(), TokenManager()
    workers = [HopaasServer(storage=storage, tokens=tokens, seed=i)
               for i in range(3)]
    runner = HttpServiceRunner(workers).start()
    yield runner, tokens
    runner.stop()


def test_http_wire_end_to_end(http_service):
    """The real socket path: stdlib HTTP server (Uvicorn role) with 3
    round-robined workers (NGINX role), JSON bodies, token in path."""
    runner, tokens = http_service
    tr = HttpTransport.from_url(runner.url)
    cl = Client(tr, tokens.issue("http-user"))
    assert cl.version()
    study = ClientStudy(name="http", client=cl,
                        properties={"x": suggestions.uniform(-5, 5),
                                    "y": suggestions.uniform(-5, 5)},
                        sampler={"name": "random"},
                        pruner={"name": "median", "n_startup_trials": 3})
    for _ in range(9):
        with study.trial() as t:
            v = quad_objective(t.params, t.should_prune)
            t.loss = v
    (s,) = [x for x in cl.studies() if x["name"] == "http"]
    assert s["n_trials"] == 9
    assert s["n_completed"] + s["n_pruned"] == 9


def test_http_rejects_bad_token(http_service):
    runner, _ = http_service
    tr = HttpTransport.from_url(runner.url)
    status, payload = tr.request("POST", "/api/ask/garbage", {"name": "x"})
    assert status == 401
