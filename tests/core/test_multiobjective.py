"""Multi-objective optimization (the paper's sec. 5 future work):
NSGA-II sampler + Pareto-front tracking through the full protocol."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.auth import TokenManager
from repro.core.client import Client, Study, suggestions
from repro.core.samplers.nsga2 import crowding_distance, non_dominated_sort
from repro.core.server import HopaasServer
from repro.core.transport import DirectTransport


def zdt1(x1: float, rest: list[float]) -> tuple[float, float]:
    g = 1.0 + 9.0 * sum(rest) / max(len(rest), 1)
    f1 = x1
    f2 = g * (1.0 - np.sqrt(x1 / g))
    return f1, f2


def _run_study(sampler: dict, n_trials: int, seed: int = 0):
    server = HopaasServer(tokens=TokenManager(), seed=seed)
    client = Client(DirectTransport(server), server.tokens.issue("mo"))
    study = Study(
        name=f"zdt1-{sampler['name']}-{seed}",
        properties={"x1": suggestions.uniform(0.0, 1.0),
                    "x2": suggestions.uniform(0.0, 1.0),
                    "x3": suggestions.uniform(0.0, 1.0)},
        directions=["minimize", "minimize"],
        sampler=sampler, client=client)
    for _ in range(n_trials):
        t = study.ask()
        f1, f2 = zdt1(t.x1, [t.x2, t.x3])
        study.tell(t, value=[float(f1), float(f2)])
    return server, study


def _hypervolume2d(front: list[tuple[float, float]],
                   ref=(1.2, 11.0)) -> float:
    """2-D hypervolume against a reference point (both minimized):
    area of the union of boxes [x_i, Rx] x [y_i, Ry]."""
    # keep the non-dominated staircase, sorted by x ascending
    pts = sorted(set(front))
    stair, best_y = [], float("inf")
    for x, y in pts:
        if y < best_y:
            stair.append((x, y))
            best_y = y
    hv, y_prev = 0.0, ref[1]
    for x, y in stair:
        if x >= ref[0] or y >= y_prev:
            continue
        hv += (ref[0] - x) * (y_prev - y)
        y_prev = y
    return hv


def test_non_dominated_sort_basics():
    Y = np.array([[0.0, 1.0], [1.0, 0.0], [0.5, 0.5], [1.0, 1.0],
                  [2.0, 2.0]])
    fronts = non_dominated_sort(Y)
    assert sorted(fronts[0].tolist()) == [0, 1, 2]
    assert fronts[1].tolist() == [3]
    assert fronts[2].tolist() == [4]


def test_crowding_distance_extremes_infinite():
    Y = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    d = crowding_distance(Y)
    assert np.isinf(d[0]) and np.isinf(d[-1])
    assert np.isfinite(d[1]) and np.isfinite(d[2])


def test_protocol_roundtrip_with_values():
    server, study = _run_study({"name": "random"}, 8)
    stored = server.storage.get_study(study.study_key)
    assert all(t.values is not None and len(t.values) == 2
               for t in stored.completed())
    front = stored.pareto_front()
    assert 1 <= len(front) <= 8
    # every front member is non-dominated
    for t in front:
        for o in stored.completed():
            assert not (o.values[0] < t.values[0]
                        and o.values[1] < t.values[1])


def test_studies_api_reports_pareto():
    server, study = _run_study({"name": "random"}, 6)
    status, payload = server.handle(
        "GET", f"/api/studies/{server.tokens.issue('x')}")
    assert status == 200
    rec = [s for s in payload["studies"] if s["key"] == study.study_key][0]
    assert "pareto_front" in rec and len(rec["pareto_front"]) >= 1


def test_nsga2_competitive_and_self_improving():
    """Random search is a strong baseline on low-dim ZDT1 (well known);
    the robust claims are (a) NSGA-II is competitive with random over
    seeds, and (b) its evolutionary phase improves on its own random
    warmup front."""
    n, pop = 120, 12
    hv_r, hv_n, hv_warm = [], [], []
    for seed in (0, 1, 2):
        srv_r, st_r = _run_study({"name": "random"}, n, seed=seed)
        srv_n, st_n = _run_study({"name": "nsga2", "population": pop}, n,
                                 seed=seed)

        def hv(server, study, first=None):
            s = server.storage.get_study(study.study_key)
            trials = s.completed()[: first] if first else s.pareto_front()
            if first:
                front = [tuple(t.values) for t in trials]
            else:
                front = [tuple(t.values) for t in trials]
            return _hypervolume2d(front)

        hv_r.append(hv(srv_r, st_r))
        hv_n.append(hv(srv_n, st_n))
        hv_warm.append(hv(srv_n, st_n, first=pop))

    med = np.median
    assert med(hv_n) >= med(hv_r) * 0.90, (hv_n, hv_r)   # competitive
    assert med(hv_n) > med(hv_warm), (hv_n, hv_warm)     # evolution helps
