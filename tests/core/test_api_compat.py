"""The v1 compat shim: every pre-router endpoint exercised through the
declarative router against the v2 core, with byte-compatible success
payloads — plus stdlib-HTTP round-trip coverage for query strings and
the Authorization header."""
import json

import pytest

from repro.core import (Client, ClientStudy, DirectTransport, HopaasServer,
                        HOPAAS_VERSION, HttpServiceRunner, HttpTransport,
                        InMemoryStorage, TokenManager, suggestions)


@pytest.fixture()
def server():
    return HopaasServer(seed=0)


@pytest.fixture()
def token(server):
    return server.tokens.issue("v1-tester")


SPEC = {"name": "compat",
        "properties": {"x": suggestions.uniform(0.0, 1.0)},
        "sampler": {"name": "random"}, "pruner": {"name": "none"}}


# --------------------------------------------------------------------- #
# byte-compatible success payloads through the shim
# --------------------------------------------------------------------- #
def test_v1_version_payload(server):
    status, payload = server.handle("GET", "/api/version")
    assert status == 200
    assert payload == {"version": HOPAAS_VERSION}


def test_v1_ask_payload_shape(server, token):
    status, payload = server.handle("POST", f"/api/ask/{token}", dict(SPEC))
    assert status == 200
    assert list(payload) == ["trial_uid", "trial_id", "study_key",
                             "properties", "study_created"]
    assert payload["trial_id"] == 0 and payload["study_created"] is True
    assert 0.0 <= payload["properties"]["x"] <= 1.0


def test_v1_ask_batch_payload_shape(server, token):
    status, payload = server.handle("POST", f"/api/ask_batch/{token}",
                                    {**SPEC, "n": 3})
    assert status == 200
    assert list(payload) == ["trials", "study_key", "study_created"]
    assert [t["trial_id"] for t in payload["trials"]] == [0, 1, 2]
    for t in payload["trials"]:
        assert list(t) == ["trial_uid", "trial_id", "study_key", "properties"]


def test_v1_tell_and_conflict(server, token):
    _, ask = server.handle("POST", f"/api/ask/{token}", dict(SPEC))
    uid = ask["trial_uid"]
    status, payload = server.handle("POST", f"/api/tell/{token}",
                                    {"trial_uid": uid, "value": 1.5})
    assert status == 200
    assert payload == {"trial_uid": uid, "state": "completed"}
    status, payload = server.handle("POST", f"/api/tell/{token}",
                                    {"trial_uid": uid, "value": 2.0})
    assert status == 409
    assert payload["detail"] == f"trial {uid} already completed"


def test_v1_tell_batch_partial_conflict(server, token):
    _, batch = server.handle("POST", f"/api/ask_batch/{token}",
                             {**SPEC, "n": 2})
    u1, u2 = [t["trial_uid"] for t in batch["trials"]]
    server.handle("POST", f"/api/tell/{token}", {"trial_uid": u1, "value": 1.0})
    status, payload = server.handle(
        "POST", f"/api/tell_batch/{token}",
        {"tells": [{"trial_uid": u1, "value": 9.0},
                   {"trial_uid": u2, "value": 2.0}]})
    assert status == 200
    r1, r2 = payload["results"]
    assert r1["status"] == 409
    assert r2["status"] == 200 and r2["trial_uid"] == u2
    assert r2["state"] == "completed"


def test_v1_should_prune_payload(server, token):
    _, ask = server.handle("POST", f"/api/ask/{token}", dict(SPEC))
    uid = ask["trial_uid"]
    status, payload = server.handle(
        "POST", f"/api/should_prune/{token}",
        {"trial_uid": uid, "step": 0, "value": 3.0})
    assert status == 200
    assert payload == {"trial_uid": uid, "should_prune": False}
    assert server.storage.get_trial(uid).intermediates == {0: 3.0}


def test_v1_studies_payload_shape(server, token):
    _, ask = server.handle("POST", f"/api/ask/{token}", dict(SPEC))
    server.handle("POST", f"/api/tell/{token}",
                  {"trial_uid": ask["trial_uid"], "value": 0.5})
    status, payload = server.handle("GET", f"/api/studies/{token}")
    assert status == 200
    (rec,) = payload["studies"]
    assert list(rec) == ["key", "name", "n_trials", "n_completed",
                         "n_pruned", "n_failed", "best_value", "best_params"]
    assert rec["n_completed"] == 1 and rec["best_value"] == 0.5


def test_v1_auth_failures_are_401(server):
    assert server.handle("POST", "/api/ask/garbage", dict(SPEC))[0] == 401
    tok = server.tokens.issue("u", ttl_seconds=-1.0)
    assert server.handle("POST", f"/api/ask/{tok}", dict(SPEC))[0] == 401


# --------------------------------------------------------------------- #
# the old 500s are now structured 4xx (satellite: malformed bodies)
# --------------------------------------------------------------------- #
def test_v1_non_dict_body_is_422(server, token):
    status, payload = server.handle("POST", f"/api/ask/{token}", [1, 2])
    assert status == 422
    assert payload["error"]["field"] == "$"


def test_v1_wrong_typed_field_is_422(server, token):
    status, payload = server.handle("POST", f"/api/tell/{token}",
                                    {"trial_uid": 7, "value": 1.0})
    assert status == 422
    assert payload["error"]["field"] == "trial_uid"


def test_v1_unknown_sampler_is_422_with_field(server, token):
    status, payload = server.handle(
        "POST", f"/api/ask/{token}",
        {**SPEC, "sampler": {"name": "simulated-annealing-9000"}})
    assert status == 422
    assert payload["error"]["code"] == "unknown_sampler"
    assert payload["error"]["field"] == "sampler.name"


def test_v1_unknown_pruner_is_422_with_field(server, token):
    status, payload = server.handle(
        "POST", f"/api/ask/{token}", {**SPEC, "pruner": {"name": "axe"}})
    assert status == 422
    assert payload["error"]["field"] == "pruner.name"


def test_v1_tell_batch_missing_list_is_422(server, token):
    status, payload = server.handle("POST", f"/api/tell_batch/{token}",
                                    {"tells": "all of them"})
    assert status == 422
    assert payload["error"]["field"] == "tells"


# --------------------------------------------------------------------- #
# full client flows through the shim (legacy _post path)
# --------------------------------------------------------------------- #
def test_legacy_client_flow_through_shim(server, token):
    client = Client(DirectTransport(server), token)
    payload = client._post("ask", dict(SPEC))
    uid = payload["trial_uid"]
    assert payload["study_created"] is True
    assert client._post("should_prune",
                        {"trial_uid": uid, "step": 1, "value": 0.4}
                        )["should_prune"] is False
    told = client._post("tell", {"trial_uid": uid, "value": 0.4})
    assert told == {"trial_uid": uid, "state": "completed"}
    batch = client._post("ask_batch", {**SPEC, "n": 2})
    results = client._post("tell_batch", {"tells": [
        {"trial_uid": t["trial_uid"], "value": 1.0}
        for t in batch["trials"]]})["results"]
    assert [r["status"] for r in results] == [200, 200]


# --------------------------------------------------------------------- #
# stdlib HTTP round trip: query strings + Authorization header survive
# --------------------------------------------------------------------- #
@pytest.fixture()
def http_service():
    storage, tokens = InMemoryStorage(), TokenManager()
    runner = HttpServiceRunner(
        [HopaasServer(storage=storage, tokens=tokens, seed=0)]).start()
    yield runner, tokens
    runner.stop()


def test_http_header_auth_and_query_string_round_trip(http_service):
    runner, tokens = http_service
    tok = tokens.issue("wire-user")
    client = Client(HttpTransport(runner.host, runner.port), tok)
    study = ClientStudy(name="wire", client=client,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"})
    trials = study.ask_batch(6)
    study.tell_batch([(t, float(i)) for i, t in enumerate(trials[:4])])

    # state filter + limit arrive server-side intact (query string), and
    # the bearer header authenticates (nothing in the URL path)
    page = client.trials_page(study.study_key, state="completed", limit=3)
    assert len(page["trials"]) == 3
    assert all(t["state"] == "completed" for t in page["trials"])
    assert page["next_cursor"] is not None
    rest = client.trials_page(study.study_key, state="completed",
                              limit=3, cursor=page["next_cursor"])
    assert len(rest["trials"]) == 1

    # missing header over the real wire -> 401
    bare = HttpTransport(runner.host, runner.port)
    status, payload = bare.request(
        "GET", f"/api/v2/studies/{study.study_key}/trials?limit=3")
    assert status == 401
    assert payload["error"]["code"] == "unauthorized"


def test_http_405_allow_header_on_the_wire(http_service):
    runner, tokens = http_service
    tr = HttpTransport(runner.host, runner.port)
    status, payload, headers = tr.request_full("GET", "/api/v2/trials:tell_batch")
    assert status == 405
    allow = next(v for k, v in headers.items() if k.lower() == "allow")
    assert allow == "POST"
    # v1 path too
    status, _, headers = tr.request_full(
        "GET", f"/api/ask/{tokens.issue('u')}")
    assert status == 405
    assert next(v for k, v in headers.items() if k.lower() == "allow") == "POST"


def test_http_malformed_json_is_400_not_500(http_service):
    """Raw socket write of a non-JSON body: structured 400, and the
    keep-alive connection survives for the next request."""
    import http.client as hc
    runner, tokens = http_service
    tok = tokens.issue("u")
    conn = hc.HTTPConnection(runner.host, runner.port, timeout=10)
    conn.request("POST", f"/api/tell/{tok}", body=b"{not json!",
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    payload = json.loads(resp.read())
    assert resp.status == 400
    assert payload["error"]["code"] == "invalid_json"
    # same connection still usable (framing survived)
    conn.request("GET", "/api/version")
    resp = conn.getresponse()
    assert resp.status == 200
    assert json.loads(resp.read())["version"] == HOPAAS_VERSION
    conn.close()


def test_http_non_dict_json_body_is_422(http_service):
    runner, tokens = http_service
    tr = HttpTransport(runner.host, runner.port)
    status, payload = tr.request("POST", f"/api/tell/{tokens.issue('u')}",
                                 body=[1, 2, 3])
    assert status == 422
    assert payload["error"]["field"] == "$"
