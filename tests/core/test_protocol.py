"""Protocol-level tests: the four REST APIs of paper Table 1."""
import threading

import pytest

from repro.core import (Client, ClientStudy, DirectTransport, HopaasError,
                        HopaasServer, HOPAAS_VERSION, suggestions)


@pytest.fixture()
def server():
    return HopaasServer(seed=0)


@pytest.fixture()
def client(server):
    tok = server.tokens.issue("tester")
    return Client(DirectTransport(server), tok)


def make_study(client, name="s", sampler=None, pruner=None):
    return ClientStudy(
        name=name,
        properties={"lr": suggestions.loguniform(1e-5, 1e-1),
                    "units": suggestions.int(8, 128),
                    "act": suggestions.categorical(["relu", "tanh"]),
                    "const_thing": 42},
        sampler=sampler or {"name": "random"},
        pruner=pruner or {"name": "none"},
        client=client)


def test_version(client):
    assert client.version() == HOPAAS_VERSION


def test_ask_returns_params_within_space(client):
    study = make_study(client)
    t = study.ask()
    assert 1e-5 <= t.params["lr"] <= 1e-1
    assert 8 <= t.params["units"] <= 128 and isinstance(t.params["units"], int)
    assert t.params["act"] in ("relu", "tanh")
    assert t.params["const_thing"] == 42          # constants pass through
    assert t.lr == t.params["lr"]                 # attribute access


def test_ask_routes_same_config_to_same_study(client):
    study = make_study(client)
    t1, t2 = study.ask(), study.ask()
    assert t1.uid.split(":")[0] == t2.uid.split(":")[0]
    assert t2.id == t1.id + 1


def test_ask_routes_different_config_to_new_study(client):
    s1, s2 = make_study(client, "a"), make_study(client, "b")
    t1, t2 = s1.ask(), s2.ask()
    assert t1.uid.split(":")[0] != t2.uid.split(":")[0]


def test_tell_finalizes_trial(server, client):
    study = make_study(client)
    t = study.ask()
    study.tell(t, value=1.5)
    stored = server.storage.get_trial(t.uid)
    assert stored.state.value == "completed"
    assert stored.value == 1.5
    assert stored.finished_at is not None


def test_tell_twice_conflicts(client):
    study = make_study(client)
    t = study.ask()
    study.tell(t, value=1.0)
    with pytest.raises(HopaasError, match="409"):
        study.tell(t, value=2.0)


def test_should_prune_records_intermediates(server, client):
    study = make_study(client)
    t = study.ask()
    assert t.should_prune(0, 5.0) is False        # NonePruner never prunes
    assert t.should_prune(1, 4.0) is False
    stored = server.storage.get_trial(t.uid)
    assert stored.intermediates == {0: 5.0, 1: 4.0}
    study.tell(t, value=4.0)


def test_trial_context_manager_reports_failure(server, client):
    study = make_study(client)
    with pytest.raises(RuntimeError, match="boom"):
        with study.trial() as t:
            raise RuntimeError("boom")
    stored = server.storage.get_study(study.study_key or t.uid.split(":")[0])
    assert stored.trials[0].state.value == "failed"


def test_bad_token_rejected(server):
    bad = Client(DirectTransport(server), "not-a-token")
    with pytest.raises(HopaasError, match="401"):
        make_study(bad).ask()


def test_revoked_token_rejected(server):
    tok = server.tokens.issue("tester")
    c = Client(DirectTransport(server), tok)
    make_study(c).ask()
    server.tokens.revoke(tok)
    with pytest.raises(HopaasError, match="401"):
        make_study(c).ask()


def test_expired_token_rejected(server):
    tok = server.tokens.issue("tester", ttl_seconds=-1.0)
    c = Client(DirectTransport(server), tok)
    with pytest.raises(HopaasError, match="401"):
        make_study(c).ask()


def test_unknown_trial_tell_404(client):
    with pytest.raises(HopaasError, match="404"):
        client._post("tell", {"trial_uid": "nope:0", "value": 1.0})


def test_studies_endpoint(client):
    study = make_study(client)
    for v in (3.0, 1.0, 2.0):
        with study.trial() as t:
            t.loss = v
    (s,) = [x for x in client.studies() if x["name"] == "s"]
    assert s["n_trials"] == 3 and s["n_completed"] == 3
    assert s["best_value"] == 1.0


def test_concurrent_asks_unique_trials(server):
    """Many threads asking concurrently must receive distinct trial ids
    (the shared-storage consistency the paper gets from PostgreSQL)."""
    tok = server.tokens.issue("tester")
    uids = []
    lock = threading.Lock()

    def go():
        c = Client(DirectTransport(server), tok)
        t = make_study(c).ask()
        with lock:
            uids.append(t.uid)

    threads = [threading.Thread(target=go) for _ in range(32)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert len(set(uids)) == 32
