"""Shared persistency + crash-restart (paper sec. 3 PostgreSQL role)."""
import json
import math
import threading

import pytest

from repro.core import (Client, ClientStudy, CorruptJournalError,
                        DirectTransport, HopaasServer, JournalStorage,
                        RoundRobinTransport, suggestions)
from repro.core.types import StudyConfig, TrialState


def _drive(server, n=10, name="j"):
    cl = Client(DirectTransport(server), server.tokens.issue("t"))
    study = ClientStudy(name=name, client=cl,
                        properties={"x": suggestions.uniform(-1, 1)},
                        sampler={"name": "random"},
                        pruner={"name": "median", "n_startup_trials": 3})
    for i in range(n):
        with study.trial() as t:
            for s in range(3):
                if t.should_prune(s, abs(t.x) + (3 - s) * 0.1):
                    break
            t.loss = abs(t.x)
    return cl


def test_journal_replay(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    srv = HopaasServer(storage=JournalStorage(path), seed=0)
    cl = _drive(srv, n=12)
    before = cl.studies()
    srv.storage.close()

    # "crash" and restart the service on the same journal
    srv2 = HopaasServer(storage=JournalStorage(path), seed=0)
    cl2 = Client(DirectTransport(srv2), srv2.tokens.issue("t"))
    after = cl2.studies()
    assert before == after

    # the restarted service keeps serving the same study
    study = ClientStudy(name="j", client=cl2,
                        properties={"x": suggestions.uniform(-1, 1)},
                        sampler={"name": "random"},
                        pruner={"name": "median", "n_startup_trials": 3})
    with study.trial() as t:
        t.loss = abs(t.x)
    (s,) = [x for x in cl2.studies() if x["name"] == "j"]
    assert s["n_trials"] == 13


def test_journal_preserves_intermediates(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    srv = HopaasServer(storage=JournalStorage(path), seed=0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="i", client=cl,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"})
    with study.trial() as t:
        t.should_prune(0, 3.0)
        t.should_prune(5, 1.0)
        t.loss = 1.0
    srv.storage.close()

    srv2 = HopaasServer(storage=JournalStorage(path))
    trial = srv2.storage.get_study(study.study_key).trials[0]
    assert trial.intermediates == {0: 3.0, 5: 1.0}
    assert trial.value == 1.0


def test_horizontally_scaled_workers_share_state():
    """N server workers + shared storage == paper's Uvicorn×N + PostgreSQL."""
    from repro.core import InMemoryStorage, TokenManager
    storage, tokens = InMemoryStorage(), TokenManager()
    workers = [HopaasServer(storage=storage, tokens=tokens, seed=i,
                            worker_name=f"uvicorn-{i}") for i in range(4)]
    tok = tokens.issue("t")
    cl = Client(RoundRobinTransport(workers), tok)
    study = ClientStudy(name="scaled", client=cl,
                        properties={"x": suggestions.uniform(-1, 1)},
                        sampler={"name": "random"})
    uids = set()
    for _ in range(12):
        with study.trial() as t:
            uids.add(t.uid)
            t.loss = abs(t.x)
    assert len(uids) == 12                       # no id collisions
    (s,) = [x for x in cl.studies() if x["name"] == "scaled"]
    assert s["n_trials"] == 12 and s["n_completed"] == 12


def test_concurrent_writers_consistent():
    storage = None
    srv = HopaasServer(seed=0)
    tok = srv.tokens.issue("t")

    def go(i):
        cl = Client(DirectTransport(srv), tok)
        study = ClientStudy(name="cc", client=cl,
                            properties={"x": suggestions.uniform(0, 1)},
                            sampler={"name": "random"})
        for _ in range(5):
            with study.trial() as t:
                t.should_prune(0, t.x)
                t.loss = t.x

    threads = [threading.Thread(target=go, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    study = next(iter(srv.storage.studies()))
    assert len(study.trials) == 40
    assert all(t.state.value == "completed" for t in study.trials)


def test_torn_tail_line_truncated_and_recovered(tmp_path):
    """A crash mid-append leaves a torn final record: replay must truncate
    exactly that record (with a warning) instead of refusing to start."""
    path = str(tmp_path / "journal.jsonl")
    srv = HopaasServer(storage=JournalStorage(path), seed=0)
    cl = _drive(srv, n=8)
    before = cl.studies()
    digest = srv.storage.state_digest()
    srv.storage.close()

    # hand-truncate the journal mid-way through its final record
    with open(path, "rb") as f:
        blob = f.read()
    last_line_start = blob.rstrip(b"\n").rfind(b"\n") + 1
    torn_at = last_line_start + (len(blob) - last_line_start) // 2
    with open(path, "wb") as f:
        f.write(blob[:torn_at])

    storage = JournalStorage(path)              # must not raise
    # one record (one mutation) was lost; everything before it survived
    assert storage.state_digest() != digest
    srv2 = HopaasServer(storage=storage, seed=0)
    cl2 = Client(DirectTransport(srv2), srv2.tokens.issue("t"))
    assert cl2.studies()                        # the study is servable
    # the file was repaired: reopening is clean and digest-stable
    storage.close()
    storage2 = JournalStorage(path)
    assert storage2.state_digest() == storage.state_digest()
    storage2.close()
    assert before                               # silence unused warning


def test_corrupt_middle_record_raises(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    srv = HopaasServer(storage=JournalStorage(path), seed=0)
    _drive(srv, n=4)
    srv.storage.close()
    lines = open(path, "rb").read().splitlines(keepends=True)
    assert len(lines) > 3
    lines[1] = b'{"op": "upd\n'                 # corruption, not a torn tail
    with open(path, "wb") as f:
        f.writelines(lines)
    with pytest.raises(CorruptJournalError):
        JournalStorage(path)


def test_wal_serialization_is_strict_json(tmp_path):
    """NaN must never reach the journal as a bare (non-JSON) literal, and
    the write-ahead ordering means a failed journal write leaves the
    in-memory state untouched (live and recovered state never diverge)."""
    path = str(tmp_path / "journal.jsonl")
    storage = JournalStorage(path)
    study, _ = storage.get_or_create_study(
        StudyConfig(name="nan", properties={}))
    t = storage.add_trial(study.key, {"x": 1.0}, None, None)
    digest = storage.state_digest()
    with pytest.raises(ValueError):
        storage.update_trial(t.uid, value=float("nan"),
                             state=TrialState.COMPLETED)
    # WAL-before-apply: the rejected mutation did not touch live state
    assert storage.get_trial(t.uid).state == TrialState.RUNNING
    assert storage.state_digest() == digest
    storage.close()
    # every line the journal *did* write parses as strict JSON, and the
    # journal replays to exactly the live (unmutated) state
    for line in open(path):
        json.loads(line, parse_constant=lambda c: (_ for _ in ()).throw(
            ValueError(f"non-strict constant {c}")))
    recovered = JournalStorage(path)
    assert recovered.state_digest() == digest
    recovered.close()


def test_non_finite_study_spec_rejected_not_half_created(tmp_path):
    """NaN anywhere in a study spec -> 422 naming the path, and a spec the
    WAL cannot serialize never leaves a half-created (memory-only) study."""
    path = str(tmp_path / "journal.jsonl")
    srv = HopaasServer(storage=JournalStorage(path), seed=0)
    tok = srv.tokens.issue("t")
    bad_spec = {"name": "nanspec",
                "properties": {"x": {"type": "uniform",
                                     "low": float("nan"), "high": 1.0}}}
    status, payload, _ = srv.handle_request(
        "POST", "/api/v2/studies", bad_spec,
        {"Authorization": f"Bearer {tok}"})
    assert status == 422
    assert payload["error"]["field"] == "properties.x.low"
    assert srv.storage.studies() == []           # nothing half-created
    # direct op callers bypass the schema but the write-ahead journal
    # still refuses: the study must not exist afterwards, live or replayed
    with pytest.raises(Exception):
        srv.op_resolve_study(bad_spec)
    assert srv.storage.studies() == []
    srv.storage.close()
    recovered = JournalStorage(path)
    assert recovered.studies() == []
    recovered.close()


def test_non_finite_value_never_corrupts_incumbent():
    """Storage-level defense: a NaN/inf objective is not an observation —
    the incumbent and the completion log must ignore it."""
    from repro.core import InMemoryStorage
    storage = InMemoryStorage()
    study, _ = storage.get_or_create_study(
        StudyConfig(name="nf", properties={}))
    good = storage.add_trial(study.key, {"x": 1.0}, None, None)
    storage.update_trial(good.uid, value=2.0, state=TrialState.COMPLETED)
    bad = storage.add_trial(study.key, {"x": 2.0}, None, None)
    storage.update_trial(bad.uid, value=float("nan"),
                         state=TrialState.COMPLETED)
    worse = storage.add_trial(study.key, {"x": 3.0}, None, None)
    storage.update_trial(worse.uid, value=3.0, state=TrialState.COMPLETED)
    assert storage.best_trial(study.key).uid == good.uid
    assert [t.uid for t in storage.completed_since(study.key, 0)] == [
        good.uid, worse.uid]
    assert math.isnan(storage.get_trial(bad.uid).value)


def test_tell_rejects_non_finite_values():
    """API boundary: NaN/±inf objective -> 422 naming the field, both on
    the v2 wire and for direct op_* callers."""
    srv = HopaasServer(seed=0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="nf", client=cl,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"})
    t = study.ask()
    for bad in (float("nan"), float("inf"), float("-inf")):
        status, payload, _ = srv.handle_request(
            "POST", f"/api/v2/trials/{t.uid}:tell",
            {"value": bad, "state": "completed"},
            {"Authorization": f"Bearer {srv.tokens.issue('t')}"})
        assert status == 422
        assert payload["error"]["field"] == "value"
        status, payload, _ = srv.handle_request(
            "POST", f"/api/v2/trials/{t.uid}:report",
            {"step": 0, "value": bad},
            {"Authorization": f"Bearer {srv.tokens.issue('t')}"})
        assert status == 422
        assert payload["error"]["field"] == "value"
    # multi-objective: the offending list slot is named
    status, payload, _ = srv.handle_request(
        "POST", f"/api/v2/trials/{t.uid}:tell",
        {"value": [0.1, float("nan")], "state": "completed"},
        {"Authorization": f"Bearer {srv.tokens.issue('t')}"})
    assert status == 422 and payload["error"]["field"] == "value[1]"
    # the trial is still RUNNING and a finite tell still lands
    study.tell(t, value=0.5)
    assert srv.storage.get_trial(t.uid).state == TrialState.COMPLETED


def test_study_key_stability():
    a = StudyConfig(name="x", properties={"p": {"type": "uniform", "low": 0, "high": 1}})
    b = StudyConfig(name="x", properties={"p": {"type": "uniform", "low": 0, "high": 1}})
    c = StudyConfig(name="x", properties={"p": {"type": "uniform", "low": 0, "high": 2}})
    assert a.key() == b.key() != c.key()
