"""Shared persistency + crash-restart (paper sec. 3 PostgreSQL role)."""
import threading

from repro.core import (Client, ClientStudy, DirectTransport, HopaasServer,
                        JournalStorage, RoundRobinTransport, suggestions)
from repro.core.types import StudyConfig


def _drive(server, n=10, name="j"):
    cl = Client(DirectTransport(server), server.tokens.issue("t"))
    study = ClientStudy(name=name, client=cl,
                        properties={"x": suggestions.uniform(-1, 1)},
                        sampler={"name": "random"},
                        pruner={"name": "median", "n_startup_trials": 3})
    for i in range(n):
        with study.trial() as t:
            for s in range(3):
                if t.should_prune(s, abs(t.x) + (3 - s) * 0.1):
                    break
            t.loss = abs(t.x)
    return cl


def test_journal_replay(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    srv = HopaasServer(storage=JournalStorage(path), seed=0)
    cl = _drive(srv, n=12)
    before = cl.studies()
    srv.storage.close()

    # "crash" and restart the service on the same journal
    srv2 = HopaasServer(storage=JournalStorage(path), seed=0)
    cl2 = Client(DirectTransport(srv2), srv2.tokens.issue("t"))
    after = cl2.studies()
    assert before == after

    # the restarted service keeps serving the same study
    study = ClientStudy(name="j", client=cl2,
                        properties={"x": suggestions.uniform(-1, 1)},
                        sampler={"name": "random"},
                        pruner={"name": "median", "n_startup_trials": 3})
    with study.trial() as t:
        t.loss = abs(t.x)
    (s,) = [x for x in cl2.studies() if x["name"] == "j"]
    assert s["n_trials"] == 13


def test_journal_preserves_intermediates(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    srv = HopaasServer(storage=JournalStorage(path), seed=0)
    cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
    study = ClientStudy(name="i", client=cl,
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"})
    with study.trial() as t:
        t.should_prune(0, 3.0)
        t.should_prune(5, 1.0)
        t.loss = 1.0
    srv.storage.close()

    srv2 = HopaasServer(storage=JournalStorage(path))
    trial = srv2.storage.get_study(study.study_key).trials[0]
    assert trial.intermediates == {0: 3.0, 5: 1.0}
    assert trial.value == 1.0


def test_horizontally_scaled_workers_share_state():
    """N server workers + shared storage == paper's Uvicorn×N + PostgreSQL."""
    from repro.core import InMemoryStorage, TokenManager
    storage, tokens = InMemoryStorage(), TokenManager()
    workers = [HopaasServer(storage=storage, tokens=tokens, seed=i,
                            worker_name=f"uvicorn-{i}") for i in range(4)]
    tok = tokens.issue("t")
    cl = Client(RoundRobinTransport(workers), tok)
    study = ClientStudy(name="scaled", client=cl,
                        properties={"x": suggestions.uniform(-1, 1)},
                        sampler={"name": "random"})
    uids = set()
    for _ in range(12):
        with study.trial() as t:
            uids.add(t.uid)
            t.loss = abs(t.x)
    assert len(uids) == 12                       # no id collisions
    (s,) = [x for x in cl.studies() if x["name"] == "scaled"]
    assert s["n_trials"] == 12 and s["n_completed"] == 12


def test_concurrent_writers_consistent():
    storage = None
    srv = HopaasServer(seed=0)
    tok = srv.tokens.issue("t")

    def go(i):
        cl = Client(DirectTransport(srv), tok)
        study = ClientStudy(name="cc", client=cl,
                            properties={"x": suggestions.uniform(0, 1)},
                            sampler={"name": "random"})
        for _ in range(5):
            with study.trial() as t:
                t.should_prune(0, t.x)
                t.loss = t.x

    threads = [threading.Thread(target=go, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    study = next(iter(srv.storage.studies()))
    assert len(study.trials) == 40
    assert all(t.state.value == "completed" for t in study.trials)


def test_study_key_stability():
    a = StudyConfig(name="x", properties={"p": {"type": "uniform", "low": 0, "high": 1}})
    b = StudyConfig(name="x", properties={"p": {"type": "uniform", "low": 0, "high": 1}})
    c = StudyConfig(name="x", properties={"p": {"type": "uniform", "low": 0, "high": 2}})
    assert a.key() == b.key() != c.key()
