"""Token verification hardening: malformed tokens must surface as
``AuthError`` (wire: 401), never a raw ``ValueError``/``binascii.Error``
(wire: 500)."""
import base64
import json

import pytest

from repro.core import AuthError, HopaasServer, TokenManager


def _forge(tm: TokenManager, body_bytes: bytes) -> str:
    """A token whose signature is valid but whose body is garbage — the
    path that used to leak decode errors past the AuthError contract."""
    body = base64.urlsafe_b64encode(body_bytes).decode().rstrip("=")
    return f"{body}.{tm._sign(body)}"


def test_verify_roundtrip_still_works():
    tm = TokenManager()
    tok = tm.issue("alice", ttl_seconds=60)
    assert tm.verify(tok)["user"] == "alice"


@pytest.mark.parametrize("token", [
    "",                                   # no dot at all
    "no-dot-here",
    "!!!not-base64!!!.aabbcc",            # body is not base64
    None,                                 # not even a string
])
def test_verify_malformed_tokens_raise_autherror(token):
    tm = TokenManager()
    with pytest.raises(AuthError):
        tm.verify(token)


@pytest.mark.parametrize("body", [
    b"\xff\xfe not json",                 # undecodable
    b"[1, 2, 3]",                         # JSON but not an object
    b'{"user": "x"}',                     # missing exp/jti claims
    b'{"exp": "soon", "jti": "a"}',       # ill-typed exp
    b'{"exp": 1e12, "jti": 42}',          # ill-typed jti
])
def test_verify_corrupt_signed_body_raises_autherror(body):
    tm = TokenManager()
    with pytest.raises(AuthError):
        tm.verify(_forge(tm, body))


def test_revoke_malformed_token_raises_autherror():
    tm = TokenManager()
    with pytest.raises(AuthError):
        tm.revoke("garbage-without-a-dot")
    with pytest.raises(AuthError):
        tm.revoke(_forge(tm, b"not json at all"))


def test_revoke_then_verify_still_works():
    tm = TokenManager()
    tok = tm.issue("bob")
    tm.revoke(tok)
    with pytest.raises(AuthError):
        tm.verify(tok)


def test_corrupt_token_is_401_not_500_on_the_wire():
    srv = HopaasServer(seed=0)
    bad = _forge(srv.tokens, b"\x00\x01 garbage")
    status, payload, _ = srv.handle_request(
        "POST", "/api/v2/studies", {"name": "x", "properties": {}},
        {"Authorization": f"Bearer {bad}"})
    assert status == 401
    assert payload["error"]["code"] == "unauthorized"

    # v1 path-token flavor of the same bug
    status, payload, _ = srv.handle_request(
        "POST", f"/api/ask/{bad}", {"name": "x", "properties": {}})
    assert status == 401


def test_expired_token_message_preserved():
    tm = TokenManager()
    tok = tm.issue("carol", ttl_seconds=-1)
    with pytest.raises(AuthError, match="expired"):
        tm.verify(tok)


def test_payload_round_trips_through_base64_padding():
    # bodies of every length mod 4 must decode (padding reconstruction)
    tm = TokenManager()
    for user in ("a", "ab", "abc", "abcd", "abcde"):
        tok = tm.issue(user)
        assert tm.verify(tok)["user"] == user
        payload = json.loads(base64.urlsafe_b64decode(
            tok.split(".")[0] + "=" * (-len(tok.split(".")[0]) % 4)))
        assert payload["user"] == user
