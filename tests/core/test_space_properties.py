"""Property-based search-space round-trip tests (moved out of
test_samplers.py so the sampler suite runs without the optional
``hypothesis`` dependency; these skip cleanly when it is absent)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.space import Param, SearchSpace  # noqa: E402

SPACE_2D = {"x": {"type": "uniform", "low": -5, "high": 5},
            "y": {"type": "uniform", "low": -5, "high": 5}}


@given(low=st.floats(-1e3, 1e3), width=st.floats(1e-3, 1e3),
       u=st.floats(0, 1))
@settings(max_examples=200, deadline=None)
def test_uniform_roundtrip(low, width, u):
    p = Param(name="p", kind="uniform", low=low, high=low + width)
    v = p.from_unit(u)
    assert low - 1e-6 <= v <= low + width + 1e-6
    assert abs(p.to_unit(v) - u) < 1e-6


@given(low=st.floats(1e-6, 1e3), ratio=st.floats(1.001, 1e6),
       u=st.floats(0, 1))
@settings(max_examples=200, deadline=None)
def test_loguniform_roundtrip(low, ratio, u):
    p = Param(name="p", kind="loguniform", low=low, high=low * ratio)
    v = p.from_unit(u)
    assert low * 0.999 <= v <= low * ratio * 1.001
    assert abs(p.to_unit(v) - u) < 1e-5


@given(low=st.integers(-100, 100), width=st.integers(1, 200),
       u=st.floats(0, 1))
@settings(max_examples=200, deadline=None)
def test_int_roundtrip(low, width, u):
    p = Param(name="p", kind="int", low=low, high=low + width)
    v = p.from_unit(u)
    assert isinstance(v, int) and low <= v <= low + width


@given(n=st.integers(1, 10), u=st.floats(0, 1))
@settings(max_examples=100, deadline=None)
def test_categorical_roundtrip(n, u):
    choices = tuple(f"c{i}" for i in range(n))
    p = Param(name="p", kind="categorical", choices=choices)
    assert p.from_unit(u) in choices


@given(st.lists(st.floats(0, 1), min_size=2, max_size=2))
@settings(max_examples=50, deadline=None)
def test_vector_roundtrip(us):
    space = SearchSpace.from_properties(SPACE_2D)
    params = space.from_unit_vector(np.array(us))
    back = space.to_unit_vector(params)
    np.testing.assert_allclose(back, np.clip(us, 0, 1), atol=1e-9)
