"""The v2 resource-oriented surface: typed schemas, bearer auth, 405s,
structured error envelopes, and index-served pagination."""
import json

import pytest

from repro.core import (Client, DirectTransport, HopaasError, HopaasServer,
                        suggestions)
from repro.core.types import TrialState


@pytest.fixture()
def server():
    return HopaasServer(seed=0)


@pytest.fixture()
def token(server):
    return server.tokens.issue("v2-tester")


@pytest.fixture()
def client(server, token):
    return Client(DirectTransport(server), token)


def bearer(token):
    return {"Authorization": f"Bearer {token}"}


SPEC = {"name": "s2",
        "properties": {"x": suggestions.uniform(0.0, 1.0),
                       "k": suggestions.int(1, 9)},
        "sampler": {"name": "random"}, "pruner": {"name": "none"}}


# --------------------------------------------------------------------- #
# resources
# --------------------------------------------------------------------- #
def test_create_study_201_then_200(server, token):
    status, payload, _ = server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC), bearer(token))
    assert status == 201 and payload["created"] is True
    key = payload["study"]["key"]
    status, payload, _ = server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC), bearer(token))
    assert status == 200 and payload["created"] is False
    assert payload["study"]["key"] == key


def test_ask_tell_report_flow(server, token):
    _, created, _ = server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC), bearer(token))
    key = created["study"]["key"]
    status, trial, _ = server.handle_request(
        "POST", f"/api/v2/studies/{key}/trials:ask", {}, bearer(token))
    assert status == 200
    assert trial["study_key"] == key and trial["state"] == "running"
    assert 0.0 <= trial["params"]["x"] <= 1.0
    uid = trial["uid"]

    status, rep, _ = server.handle_request(
        "POST", f"/api/v2/trials/{uid}:report",
        {"step": 0, "value": 0.5}, bearer(token))
    assert status == 200 and rep["should_prune"] is False

    status, told, _ = server.handle_request(
        "POST", f"/api/v2/trials/{uid}:tell", {"value": 0.25}, bearer(token))
    assert status == 200 and told == {"uid": uid, "state": "completed"}

    status, got, _ = server.handle_request(
        "GET", f"/api/v2/trials/{uid}", None, bearer(token))
    assert status == 200
    assert got["trial"]["value"] == 0.25
    assert got["trial"]["state"] == "completed"
    assert got["trial"]["last_step"] == 0

    status, study, _ = server.handle_request(
        "GET", f"/api/v2/studies/{key}", None, bearer(token))
    assert status == 200
    assert study["study"]["n_completed"] == 1
    assert study["study"]["best_value"] == 0.25
    assert study["study"]["sampler"] == "random"


def test_ask_unknown_study_404(server, token):
    status, payload, _ = server.handle_request(
        "POST", "/api/v2/studies/deadbeef/trials:ask", {}, bearer(token))
    assert status == 404
    assert payload["error"]["code"] == "study_not_found"


def test_tell_conflict_409_envelope(server, token):
    _, created, _ = server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC), bearer(token))
    key = created["study"]["key"]
    _, trial, _ = server.handle_request(
        "POST", f"/api/v2/studies/{key}/trials:ask", {}, bearer(token))
    for expected in (200, 409):
        status, payload, _ = server.handle_request(
            "POST", f"/api/v2/trials/{trial['uid']}:tell",
            {"value": 1.0}, bearer(token))
        assert status == expected
    assert payload["error"]["code"] == "conflict"


def test_ask_batch_and_tell_batch(server, token):
    _, created, _ = server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC), bearer(token))
    key = created["study"]["key"]
    status, payload, _ = server.handle_request(
        "POST", f"/api/v2/studies/{key}/trials:ask_batch",
        {"n": 4}, bearer(token))
    assert status == 200 and len(payload["trials"]) == 4
    tells = [{"trial_uid": t["uid"], "value": float(i)}
             for i, t in enumerate(payload["trials"])]
    tells.append({"trial_uid": "nope:0", "value": 9.9})
    status, result, _ = server.handle_request(
        "POST", "/api/v2/trials:tell_batch", {"tells": tells}, bearer(token))
    assert status == 200
    statuses = [r["status"] for r in result["results"]]
    assert statuses == [200, 200, 200, 200, 404]
    assert result["results"][-1]["error"]["code"] == "trial_not_found"


# --------------------------------------------------------------------- #
# auth: bearer header, not URL path
# --------------------------------------------------------------------- #
def test_missing_auth_header_401(server):
    status, payload, _ = server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC), {})
    assert status == 401
    assert payload["error"]["code"] == "unauthorized"


@pytest.mark.parametrize("header", [
    "not-a-token", "Basic abc", "Bearer", "Bearer   "])
def test_malformed_auth_header_401(server, header):
    status, payload, _ = server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC), {"Authorization": header})
    assert status == 401


def test_bearer_header_is_case_insensitive(server, token):
    status, _, _ = server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC),
        {"authorization": f"bearer {token}"})
    assert status == 201


def test_revoked_token_401(server, token):
    server.tokens.revoke(token)
    status, payload, _ = server.handle_request(
        "GET", "/api/v2/studies", None, bearer(token))
    assert status == 401


def test_version_and_openapi_need_no_auth(server):
    status, payload, _ = server.handle_request("GET", "/api/v2/version")
    assert status == 200 and "version" in payload
    status, doc, _ = server.handle_request("GET", "/api/v2/openapi")
    assert status == 200 and doc["openapi"].startswith("3.")


# --------------------------------------------------------------------- #
# validation: 422 with the offending field, never a 500
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("body,field", [
    ([1, 2, 3], "$"),                                     # non-dict JSON
    ("a string", "$"),
    ({"name": 7}, "name"),                                # wrong-typed field
    ({"direction": "sideways"}, "direction"),
    ({"sampler": "tpe"}, "sampler"),                      # spec must be dict
    ({"sampler": {"name": "gradient-descent"}}, "sampler.name"),
    ({"pruner": {"name": "chainsaw"}}, "pruner.name"),
    ({"directions": ["minimize", "upward"]}, "directions[1]"),
])
def test_create_study_validation_422(server, token, body, field):
    status, payload, _ = server.handle_request(
        "POST", "/api/v2/studies", body, bearer(token))
    assert status == 422, payload
    assert payload["error"]["field"] == field


def test_bad_space_spec_is_422_not_500(server, token):
    status, payload, _ = server.handle_request(
        "POST", "/api/v2/studies",
        {"properties": {"x": {"type": "warp", "low": 0}}}, bearer(token))
    assert status == 422
    assert payload["error"]["field"] == "properties"
    # the poisoned spec must not have left a half-created study behind
    assert server.storage.studies() == []


def test_bad_sampler_kwargs_is_422(server, token):
    status, payload, _ = server.handle_request(
        "POST", "/api/v2/studies",
        {"sampler": {"name": "random", "bogus_knob": 3}}, bearer(token))
    assert status == 422
    assert payload["error"]["field"] == "sampler"


@pytest.mark.parametrize("body,field", [
    ({"value": "high"}, "value"),
    ({"value": [1.0, "x"]}, "value"),
    ({"value": []}, "value"),
    ({"value": 1.0, "state": "meditating"}, "state"),
])
def test_tell_validation_422(server, token, client, body, field):
    from repro.core import ClientStudy
    study = ClientStudy(name="v", properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"}, client=client)
    t = study.ask()
    status, payload, _ = server.handle_request(
        "POST", f"/api/v2/trials/{t.uid}:tell", body, bearer(token))
    assert status == 422
    assert payload["error"]["field"] == field


def test_ask_batch_n_validation(server, token):
    _, created, _ = server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC), bearer(token))
    key = created["study"]["key"]
    for bad in ({"n": 0}, {"n": "five"}, {"n": 1.5}):
        status, payload, _ = server.handle_request(
            "POST", f"/api/v2/studies/{key}/trials:ask_batch",
            bad, bearer(token))
        assert status == 422
        assert payload["error"]["field"] == "n"


def test_tell_batch_item_validation_names_the_item(server, token):
    status, payload, _ = server.handle_request(
        "POST", "/api/v2/trials:tell_batch",
        {"tells": [{"trial_uid": "a:0"}, {"value": 1.0}]}, bearer(token))
    assert status == 422
    assert payload["error"]["field"] == "tells[1].trial_uid"


def test_bad_query_params_422(server, token):
    _, created, _ = server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC), bearer(token))
    key = created["study"]["key"]
    for qs, field in (("limit=lots", "limit"), ("limit=0", "limit"),
                      ("cursor=x", "cursor"), ("state=zombie", "state")):
        status, payload, _ = server.handle_request(
            "GET", f"/api/v2/studies/{key}/trials?{qs}", None, bearer(token))
        assert status == 422, qs
        assert payload["error"]["field"] == field


# --------------------------------------------------------------------- #
# wrong method on a known path -> 405 + Allow (v1 and v2)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("method,path,allow", [
    ("GET", "/api/v2/trials:tell_batch", "POST"),
    ("GET", "/api/v2/studies/somekey/trials:ask", "POST"),
    ("POST", "/api/v2/version", "GET"),
    ("GET", "/api/ask/sometoken", "POST"),
    ("POST", "/api/studies/sometoken", "GET"),
])
def test_405_with_allow_header(server, method, path, allow):
    status, payload, headers = server.handle_request(method, path)
    assert status == 405
    assert headers["Allow"] == allow
    assert payload["error"]["code"] == "method_not_allowed"


def test_get_and_post_both_allowed_on_studies_collection(server, token):
    # /api/v2/studies accepts both; neither must 405
    assert server.handle_request(
        "GET", "/api/v2/studies", None, bearer(token))[0] == 200
    assert server.handle_request(
        "POST", "/api/v2/studies", dict(SPEC), bearer(token))[0] == 201


def test_unknown_path_is_404(server):
    status, payload, _ = server.handle_request("GET", "/api/v2/nonsense")
    assert status == 404
    assert payload["error"]["code"] == "not_found"


# --------------------------------------------------------------------- #
# pagination off the state-bucket indices (no trial-list scans)
# --------------------------------------------------------------------- #
class _ScanCountingTrials(list):
    """Stands in for a shard's trial list: any full iteration counts as a
    scan on the storage's counter.  Slicing (the unfiltered page path) is
    direct indexing and intentionally does not count."""

    def __init__(self, items, storage):
        super().__init__(items)
        self._storage = storage

    def __iter__(self):
        self._storage.trial_scans += 1
        return super().__iter__()


def _populated_study(server, token, n=30):
    client = Client(DirectTransport(server), token)
    from repro.core import ClientStudy
    study = ClientStudy(name="pag",
                        properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"}, client=client)
    trials = study.ask_batch(n)
    for i, t in enumerate(trials):
        if i % 3 == 0:
            continue                      # leave RUNNING
        study.tell(t, value=float(i),
                   state="failed" if i % 3 == 2 else "completed")
    return study.study_key


def test_trials_pagination_with_state_filter(server, token):
    key = _populated_study(server, token, n=30)
    shard = server.storage._shard(key)
    shard.study.trials = _ScanCountingTrials(shard.study.trials,
                                             server.storage)
    server.storage.trial_scans = 0

    seen = []
    cursor = None
    while True:
        qs = f"state=completed&limit=4" + (
            f"&cursor={cursor}" if cursor is not None else "")
        status, page, _ = server.handle_request(
            "GET", f"/api/v2/studies/{key}/trials?{qs}", None, bearer(token))
        assert status == 200
        assert len(page["trials"]) <= 4
        seen.extend(page["trials"])
        cursor = page["next_cursor"]
        if cursor is None:
            break
    assert [t["state"] for t in seen] == ["completed"] * 10
    # trial_id-ordered, no duplicates across pages
    ids = [t["trial_id"] for t in seen]
    assert ids == sorted(ids) and len(set(ids)) == len(ids)
    # the acceptance criterion: answered from the state buckets, with
    # zero walks of the trial list
    assert server.storage.trial_scans == 0


def test_unfiltered_pagination_slices_without_scanning(server, token):
    key = _populated_study(server, token, n=12)
    shard = server.storage._shard(key)
    shard.study.trials = _ScanCountingTrials(shard.study.trials,
                                             server.storage)
    server.storage.trial_scans = 0
    status, page, _ = server.handle_request(
        "GET", f"/api/v2/studies/{key}/trials?limit=5&cursor=3",
        None, bearer(token))
    assert status == 200
    assert [t["trial_id"] for t in page["trials"]] == [4, 5, 6, 7, 8]
    assert page["next_cursor"] == 8
    assert server.storage.trial_scans == 0


def test_scan_counter_is_live(server, token):
    """Guard against a vacuous counter: both the storage's instrumented
    slow-path helper and the test wrapper's iteration hook must bump it —
    these are the instruments the zero-scan assertions above rely on."""
    key = _populated_study(server, token, n=6)
    shard = server.storage._shard(key)
    shard.study.trials = _ScanCountingTrials(shard.study.trials,
                                             server.storage)
    server.storage.trial_scans = 0
    scanned = server.storage._scan_trials(shard)   # designated slow path
    assert len(scanned) == 6
    assert server.storage.trial_scans >= 1
    before = server.storage.trial_scans
    assert any(t.state == TrialState.COMPLETED
               for t in shard.study.trials)        # a real full iteration
    assert server.storage.trial_scans == before + 1


def test_studies_list_pagination(server, token):
    for i in range(5):
        spec = dict(SPEC, name=f"multi-{i}")
        server.handle_request("POST", "/api/v2/studies", spec, bearer(token))
    status, p1, _ = server.handle_request(
        "GET", "/api/v2/studies?limit=2", None, bearer(token))
    assert status == 200 and len(p1["studies"]) == 2
    assert p1["next_cursor"] is not None
    status, p2, _ = server.handle_request(
        "GET", f"/api/v2/studies?limit=3&cursor={p1['next_cursor']}",
        None, bearer(token))
    assert len(p2["studies"]) == 3
    names = [s["name"] for s in p1["studies"] + p2["studies"]]
    assert names == [f"multi-{i}" for i in range(5)]


# --------------------------------------------------------------------- #
# client-side v2 ergonomics
# --------------------------------------------------------------------- #
def test_client_iter_trials_paginates(client):
    from repro.core import ClientStudy
    study = ClientStudy(name="it", properties={"x": suggestions.uniform(0, 1)},
                        sampler={"name": "random"}, client=client)
    trials = study.ask_batch(7)
    study.tell_batch([(t, float(i)) for i, t in enumerate(trials)])
    got = list(client.iter_trials(study.study_key, state="completed",
                                  page_size=3))
    assert len(got) == 7
    assert all(t["state"] == "completed" for t in got)


def test_client_error_carries_code_and_field(client):
    with pytest.raises(HopaasError) as ei:
        client.tell("nope:0", value=1.0)
    assert ei.value.status == 404
    assert ei.value.code == "trial_not_found"
    err_payloads_are_json = json.dumps(ei.value.payload)
    assert "trial_not_found" in err_payloads_are_json
