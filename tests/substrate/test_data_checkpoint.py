"""Data determinism + checkpoint atomicity/restore/resharding."""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import registry


@pytest.fixture(scope="module")
def mcfg():
    return registry.get_config("deepseek-7b", smoke=True)


def test_batches_deterministic(mcfg):
    d1 = SyntheticLMDataset(DataConfig(8, 32, seed=7), mcfg)
    d2 = SyntheticLMDataset(DataConfig(8, 32, seed=7), mcfg)
    for i in (0, 5, 1000):
        np.testing.assert_array_equal(d1[i]["tokens"], d2[i]["tokens"])
    assert not np.array_equal(d1[0]["tokens"], d1[1]["tokens"])


def test_host_sharding_partitions_global_batch(mcfg):
    full = SyntheticLMDataset(DataConfig(8, 16, seed=3), mcfg)
    h0 = SyntheticLMDataset(DataConfig(8, 16, seed=3, host_index=0,
                                       host_count=2), mcfg)
    h1 = SyntheticLMDataset(DataConfig(8, 16, seed=3, host_index=1,
                                       host_count=2), mcfg)
    assert h0[0]["tokens"].shape == (4, 16)
    assert full[0]["tokens"].shape == (8, 16)
    assert not np.array_equal(h0[0]["tokens"], h1[0]["tokens"])


def test_labels_are_shifted_tokens(mcfg):
    ds = SyntheticLMDataset(DataConfig(4, 32, seed=1), mcfg)
    b = ds[0]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_audio_batch_shapes():
    cfg = registry.get_config("hubert-xlarge", smoke=True)
    ds = SyntheticLMDataset(DataConfig(4, 16, seed=0), cfg)
    b = ds[0]
    assert b["features"].shape == (4, 16, cfg.frontend_dim)
    assert b["frame_mask"].dtype == bool
    assert b["labels"].max() < cfg.vocab_size


# ------------------------------------------------------------------ #
def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    p = str(tmp_path / "ck.npz")
    save_tree(p, tree, {"step": 3})
    like = {"a": jnp.zeros((2, 3), jnp.float32),
            "b": {"c": jnp.zeros((4,), jnp.bfloat16)}}
    out = restore_tree(p, like)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_manager_latest_prune_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((3,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"w": jnp.full((3,), float(s))}, blocking=True)
    assert mgr.all_steps() == [3, 4]                 # pruned to keep=2
    out, meta = mgr.restore_latest(tree)
    assert meta["step"] == 4
    np.testing.assert_array_equal(np.asarray(out["w"]), np.full((3,), 4.0))


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(7, {"w": jnp.ones((2,))}, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_crash_mid_save_leaves_no_corruption(tmp_path):
    """A stray .tmp file (simulated crash) is invisible to the manager."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"w": jnp.ones((2,))}, blocking=True)
    with open(os.path.join(str(tmp_path), "step_00000002.npz.tmp"), "wb") as f:
        f.write(b"garbage")
    assert mgr.latest_step() == 1
    out, _ = mgr.restore_latest({"w": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((2,)))
