"""AdamW / schedules / compression."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import (AdamWConfig, adamw_init, adamw_update, compress_int8,
                         cosine_warmup, decompress_int8, global_norm)


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    opt = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-3 * l0


def test_grad_clip_bounds_update():
    params = {"w": jnp.ones((4, 4))}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    opt = adamw_init(params, cfg)
    huge = {"w": jnp.full((4, 4), 1e6)}
    _, _, metrics = adamw_update(huge, opt, params, cfg)
    assert float(metrics["grad_norm"]) > 1e5       # reported pre-clip


def test_weight_decay_only_on_matrices():
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, grad_clip=0.0)
    opt = adamw_init(params, cfg)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(zero_g, opt, params, cfg)
    assert float(jnp.abs(new_p["w"]).max()) < 1.0   # decayed
    assert np.allclose(new_p["b"], params["b"])     # not decayed


def test_cosine_warmup_shape():
    f = cosine_warmup(1.0, warmup=10, total=100)
    lrs = [float(f(jnp.int32(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 0.01
    assert lrs[-1] <= 0.2                           # decayed to ~floor


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.01, 100.0))
def test_int8_compression_roundtrip_error_bounded(seed, scale):
    """Property: |x - dec(enc(x))| <= max|row| / 127 elementwise."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((8, 32)) * scale, jnp.float32)
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s)) - np.asarray(x))
    bound = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True) / 127.0
    assert (err <= bound + 1e-6).all()
    assert q.dtype == jnp.int8


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-5
