"""repro.dist.sharding: divisibility-safe logical->mesh mapping."""
from __future__ import annotations

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs >=4 host devices (run via runner)")


def _mesh(shape, axes):
    return jax.make_mesh(shape, axes)


@pytest.fixture(scope="module")
def mesh():
    n = jax.device_count()
    return _mesh((n // 2, 2), ("data", "model"))


def test_basic_fsdp_tp(mesh):
    p = shd.logical_to_pspec(("embed", "mlp"), (64, 128), mesh,
                             shd.RULES_TRAIN)
    assert p == P("data", "model")


def test_heads_fallback_to_head_dim(mesh):
    # 3 heads % model(2) != 0 -> heads replicate, head_dim takes model
    p = shd.logical_to_pspec(("embed", "heads", "head_dim"), (64, 3, 128),
                             mesh, shd.RULES_TRAIN)
    assert p == P("data", None, "model")


def test_no_double_use_of_axis(mesh):
    # both dims want model; only the first gets it
    p = shd.logical_to_pspec(("heads", "head_dim"), (8, 128), mesh,
                             shd.RULES_TRAIN)
    assert p == P("model", None)


def test_embed_twice(mesh):
    p = shd.logical_to_pspec(("embed", "embed"), (64, 64), mesh,
                             shd.RULES_TRAIN)
    assert p == P("data", None)


def test_uneven_vocab_replicates(mesh):
    p = shd.logical_to_pspec(("embed", "vocab"), (64, 503), mesh,
                             shd.RULES_TRAIN)
    assert p == P("data", None)


def test_batch_one_replicates(mesh):
    assert shd.batch_axis(mesh, 1) is None
    assert shd.batch_axis(mesh, 64) is not None


def test_pod_axis_only_when_present(mesh):
    # single-pod mesh has no "pod" axis; batch falls through to data
    p = shd.logical_to_pspec(("batch",), (32,), mesh, shd.RULES_TRAIN)
    assert p == P("data")


def test_multipod_batch():
    n = jax.device_count()
    if n < 8:
        pytest.skip("needs 8 devices")
    mesh3 = _mesh((2, n // 4, 2), ("pod", "data", "model"))
    p = shd.logical_to_pspec(("batch",), (32,), mesh3, shd.RULES_TRAIN)
    assert p == P(("pod", "data"))


def test_real_param_tree_end_to_end(mesh):
    from repro.models import registry, transformer
    cfg = registry.get_config("deepseek-67b")      # abstract init: no alloc
    params, specs = transformer.init_params(cfg, None)
    shardings = shd.tree_shardings(specs, params, mesh, shd.RULES_TRAIN)
    flat = jax.tree.leaves(shardings)
    assert flat and all(s.mesh.shape == mesh.shape for s in flat)
    # the big matmul weights must actually shard over both axes
    ps = shd.tree_pspecs(specs, params, mesh, shd.RULES_TRAIN)
    up = ps["blocks"]["mlp"]["up"]
    assert up == P(None, "data", "model")          # (layers, d_model, d_ff)
