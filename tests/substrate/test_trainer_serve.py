"""Trainer loop (loss decrease, checkpoint/restart, prune hook) and the
serving engine (decode == forward)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig
from repro.models import registry, transformer
from repro.optim import AdamWConfig
from repro.serve import ServeEngine
from repro.train import Trainer, TrainerConfig


def _cfgs(total_steps=30, ckpt_dir=None, ckpt_every=0, micro=1):
    mcfg = registry.get_config("deepseek-7b", smoke=True).replace(
        n_layers=2, d_model=64, d_ff=128, vocab_size=128)
    opt = AdamWConfig(lr=3e-3, weight_decay=0.0)
    dcfg = DataConfig(global_batch=8, seq_len=32, seed=0)
    tcfg = TrainerConfig(total_steps=total_steps, microbatches=micro,
                         report_every=5, checkpoint_every=ckpt_every,
                         checkpoint_dir=ckpt_dir)
    return mcfg, opt, dcfg, tcfg


def test_loss_decreases():
    res = Trainer(*_cfgs(total_steps=40)).run()
    assert res.steps_run == 40
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.1, (first, last)


def test_prune_hook_stops_training():
    calls = []

    def report(step, loss):
        calls.append(step)
        return step >= 10          # prune at the 2nd report

    res = Trainer(*_cfgs(total_steps=100)).run(report=report)
    assert res.pruned
    assert res.steps_run == 10
    assert calls == [5, 10]


def test_checkpoint_restart_resumes_exactly(tmp_path):
    """Fault tolerance: train 20; kill; restart -> identical final loss to
    an uninterrupted 20-step run (deterministic pipeline + state restore)."""
    d1 = str(tmp_path / "a")
    r_full = Trainer(*_cfgs(total_steps=20, ckpt_dir=None)).run()

    t = Trainer(*_cfgs(total_steps=10, ckpt_dir=d1, ckpt_every=10))
    t.run()                                   # first 10 steps + checkpoint
    t2 = Trainer(*_cfgs(total_steps=20, ckpt_dir=d1, ckpt_every=10))
    r_resumed = t2.run()                      # restores at step 10
    assert r_resumed.restored_from == 10
    assert r_resumed.steps_run == 10
    np.testing.assert_allclose(r_resumed.final_loss, r_full.final_loss,
                               rtol=1e-4)


def test_microbatched_trainer_runs():
    res = Trainer(*_cfgs(total_steps=6, micro=4)).run()
    assert res.steps_run == 6
    assert np.isfinite(res.final_loss)


def test_serve_engine_greedy_matches_argmax_forward():
    mcfg = registry.get_config("deepseek-7b", smoke=True)
    params, _ = transformer.init_params(mcfg, jax.random.key(1))
    eng = ServeEngine(mcfg, params, max_len=32)
    prompts = np.asarray(
        jax.random.randint(jax.random.key(2), (2, 5), 0, mcfg.vocab_size),
        np.int32)
    out = eng.generate(prompts, n_new=3)
    assert out.shape == (2, 3)
    # first generated token == argmax of the full-sequence forward
    logits, _ = transformer.forward(params, mcfg,
                                    {"tokens": jnp.asarray(prompts)})
    expect = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
    np.testing.assert_array_equal(out[:, 0], expect)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "rwkv6-7b", "zamba2-1.2b"])
def test_serve_engine_stateful_archs(arch):
    mcfg = registry.get_config(arch, smoke=True)
    params, _ = transformer.init_params(mcfg, jax.random.key(1))
    eng = ServeEngine(mcfg, params, max_len=16)
    prompts = np.zeros((1, 4), np.int32)
    out = eng.generate(prompts, n_new=2)
    assert out.shape == (1, 2)
