"""Unit tests for the loop-aware HLO cost analyzer (the roofline's
measurement instrument)."""
from __future__ import annotations

from repro.launch import hlo_cost

HLO = """
HloModule test

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%cond.1 (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %init = (s32[], f32[8,8]) tuple(%a, %a)
  %w2 = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_shape_bytes():
    assert hlo_cost._shape_bytes("f32[8,8]{1,0}") == 256
    assert hlo_cost._shape_bytes("bf16[4,2]") == 16
    assert hlo_cost._shape_bytes("(f32[2], f32[2])") == 16
    assert hlo_cost._shape_bytes("s8[10]") == 10
    assert hlo_cost._shape_bytes("f32[]") == 4


def test_parse_module_structure():
    comps, entry = hlo_cost.parse_module(HLO)
    assert entry == "%main"
    assert "%body.1" in comps
    ops = [i.opcode for i in comps["%body.1"]]
    assert "dot" in ops and "all-reduce" in ops


def test_loop_multiplier_applies():
    cost = hlo_cost.analyze(HLO, total_devices=8)
    # dot: 2 * 8*8 out * 8 contract = 1024 flops, x5 trips
    assert cost.flops == 1024 * 5
    # all-reduce: 2 * 256 * (4-1)/4 = 384 bytes, x5 trips
    assert cost.collective_bytes == 384 * 5
    assert cost.collective_calls["all-reduce"] == 5
    assert cost.unknown_loops == 0


def test_group_size_parsing():
    assert hlo_cost._group_size("replica_groups=[2,4]<=[8]", 8) == 4
    assert hlo_cost._group_size("replica_groups={{0,1,2}}", 8) == 3
    assert hlo_cost._group_size("no groups here", 8) == 8


def test_traffic_model():
    t = hlo_cost._TRAFFIC
    assert t["all-gather"](100, 4) == 75.0
    assert t["all-reduce"](100, 4) == 150.0
    assert t["reduce-scatter"](100, 4) == 300.0
    assert t["collective-permute"](100, 4) == 100.0
