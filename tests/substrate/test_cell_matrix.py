"""The assigned (arch x shape) matrix: 32 cells, with the documented
skips, and coherent per-cell configuration."""
from __future__ import annotations

import jax.numpy as jnp
import pytest

from repro.launch import shapes as shp
from repro.models import registry


def test_cell_count_is_32():
    cells = shp.cells()
    assert len(cells) == 32


def test_skips_are_exactly_the_documented_ones():
    cells = set(shp.cells())
    # encoder-only: no decode shapes
    assert ("hubert-xlarge", "decode_32k") not in cells
    assert ("hubert-xlarge", "long_500k") not in cells
    # full attention: no 500k
    for arch in ("qwen1.5-32b", "deepseek-67b", "deepseek-7b", "qwen3-32b",
                 "pixtral-12b", "qwen2-moe-a2.7b"):
        assert (arch, "long_500k") not in cells, arch
    # sub-quadratic archs keep it
    for arch in ("zamba2-1.2b", "mixtral-8x7b", "rwkv6-7b"):
        assert (arch, "long_500k") in cells, arch
    # everyone trains and prefills
    for arch, _ in cells:
        assert (arch, "train_4k") in cells
        assert (arch, "prefill_32k") in cells


def test_configure_for_cell_serving_dtypes():
    cfg = registry.get_config("deepseek-67b")
    dec = shp.configure_for_cell(cfg, shp.SHAPES["decode_32k"])
    assert dec.param_dtype == jnp.bfloat16
    assert dec.kv_quant                      # int8 cache for the big arch
    pre = shp.configure_for_cell(cfg, shp.SHAPES["prefill_32k"])
    assert pre.attn_impl == "blocked"
    trn = shp.configure_for_cell(cfg, shp.SHAPES["train_4k"])
    assert trn.param_dtype == jnp.float32    # f32 masters for training


def test_qwen15_prefill_pads_heads():
    cfg = registry.get_config("qwen1.5-32b")
    pre = shp.configure_for_cell(cfg, shp.SHAPES["prefill_32k"])
    assert pre.n_heads == 48 and pre.n_kv_heads == 48
    dec = shp.configure_for_cell(cfg, shp.SHAPES["decode_32k"])
    assert dec.n_heads == 40                 # decode keeps faithful heads


def test_swa_decode_cache_is_window_bounded():
    cfg = registry.get_config("mixtral-8x7b")
    c = shp.configure_for_cell(cfg, shp.SHAPES["long_500k"])
    assert shp.decode_cache_len(c, shp.SHAPES["long_500k"]) == 4096


def test_input_specs_have_no_arrays():
    import jax
    for arch, shape in [("mixtral-8x7b", "decode_32k"),
                        ("hubert-xlarge", "train_4k"),
                        ("pixtral-12b", "prefill_32k")]:
        specs = shp.input_specs(arch, shape)
        specs.pop("cache_logical", None)     # logical-axes tuples
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
