"""Property tests: the sharding rulebook's invariants hold for arbitrary
logical-axis/shape combinations (single-device safe — pure spec math)."""
from __future__ import annotations

import jax
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.dist import sharding as shd

AXES = [None, "embed", "mlp", "heads", "kv_heads", "head_dim", "vocab",
        "experts", "layers", "batch", "seq"]


class _FakeMesh:
    """Just enough mesh for logical_to_pspec (shape lookup)."""
    def __init__(self, shape):
        self.shape = shape


MESHES = [
    _FakeMesh({"data": 16, "model": 16}),
    _FakeMesh({"pod": 2, "data": 16, "model": 16}),
    _FakeMesh({"data": 4, "model": 2}),
]


@settings(max_examples=200, deadline=None)
@given(
    mesh_i=st.integers(0, len(MESHES) - 1),
    rules_name=st.sampled_from(["train", "decode", "train_ep",
                                "prefill_sp"]),
    dims=st.lists(
        st.tuples(st.sampled_from(AXES), st.integers(1, 4096)),
        min_size=1, max_size=5),
)
def test_pspec_invariants(mesh_i, rules_name, dims):
    mesh = MESHES[mesh_i]
    rules = shd.get_rules(rules_name)
    logical = tuple(d[0] for d in dims)
    shape = tuple(d[1] for d in dims)
    spec = shd.logical_to_pspec(logical, shape, mesh, rules)

    used = []
    for entry, dim in zip(spec, shape):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        for a in axes:
            # (1) every mesh axis exists and is used at most once
            assert a in mesh.shape
            assert a not in used, f"axis {a} used twice in {spec}"
            used.append(a)
        # (2) the dim divides evenly (XLA rejects uneven shards)
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        assert dim % extent == 0, (dim, extent, spec)


@settings(max_examples=50, deadline=None)
@given(dims=st.lists(st.tuples(st.sampled_from(AXES),
                               st.sampled_from([1, 2, 3, 16, 128, 4096])),
                     min_size=1, max_size=4))
def test_pspec_deterministic(dims):
    mesh = MESHES[0]
    logical = tuple(d[0] for d in dims)
    shape = tuple(d[1] for d in dims)
    a = shd.logical_to_pspec(logical, shape, mesh, shd.RULES_TRAIN)
    b = shd.logical_to_pspec(logical, shape, mesh, shd.RULES_TRAIN)
    assert a == b
