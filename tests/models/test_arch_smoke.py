"""Per-arch smoke tests: reduced config, one forward + one train-grad step
on CPU, asserting output shapes and no NaNs.  Decode smoke for every arch
that supports it (cache round-trip against full-sequence forward)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry, transformer
from repro.models.config import ModelConfig

ARCHS = ["qwen1.5-32b", "deepseek-67b", "deepseek-7b", "qwen3-32b",
         "zamba2-1.2b", "pixtral-12b", "qwen2-moe-a2.7b", "mixtral-8x7b",
         "rwkv6-7b", "hubert-xlarge"]

B, S = 2, 16


def make_batch(cfg: ModelConfig, key: jax.Array, batch: int = B,
               seq: int = S) -> dict:
    ks = jax.random.split(key, 4)
    if cfg.frontend == "audio":
        return {
            "features": jax.random.normal(ks[0], (batch, seq, cfg.frontend_dim),
                                          jnp.float32),
            "frame_mask": jax.random.bernoulli(ks[1], 0.3, (batch, seq)),
            "labels": jax.random.randint(ks[2], (batch, seq), 0, cfg.vocab_size),
        }
    b = {"tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size),
         "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        n_patch = 8
        b["patch_embeds"] = jax.random.normal(
            ks[2], (batch, n_patch, cfg.frontend_dim), jnp.float32)
    return b


@pytest.fixture(scope="module")
def rng():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = registry.get_config(arch, smoke=True)
    params, _ = transformer.init_params(cfg, rng)
    batch = make_batch(cfg, rng)
    logits, aux = jax.jit(
        lambda p, b: transformer.forward(p, cfg, b))(params, batch)
    S_out = S + (8 if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch, rng):
    cfg = registry.get_config(arch, smoke=True)
    params, _ = transformer.init_params(cfg, rng)
    batch = make_batch(cfg, rng)

    def loss(p):
        l, _ = transformer.loss_fn(p, cfg, batch)
        return l

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert-xlarge"])
def test_decode_matches_forward(arch, rng):
    """Prefill via repeated decode == full-sequence forward (last logits)."""
    cfg = registry.get_config(arch, smoke=True)
    if cfg.frontend == "vision":
        cfg = cfg.replace(frontend=None)       # decode drives the text stream
    params, _ = transformer.init_params(cfg, rng)
    seq = 8
    tokens = jax.random.randint(rng, (B, seq), 0, cfg.vocab_size)
    ref_logits, _ = transformer.forward(params, cfg, {"tokens": tokens})

    cache, _ = transformer.init_cache_arrays(cfg, B, max_len=seq)
    step = jax.jit(lambda p, c, t, n: transformer.decode_step(p, cfg, c, t, n))
    for t in range(seq):
        logits, cache = step(params, cache, tokens[:, t: t + 1],
                             jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(ref_logits[:, -1], np.float32), rtol=2e-2, atol=2e-2)


def test_full_configs_have_assigned_dims():
    spec = {
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = registry.get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, h, kv, ff, v), (arch, got)


def test_param_counts_in_expected_ballpark():
    """Sanity: analytic param counts land near the names' billions."""
    expect = {"deepseek-67b": (60e9, 75e9), "deepseek-7b": (6e9, 8e9),
              "qwen1.5-32b": (28e9, 36e9), "qwen3-32b": (28e9, 36e9),
              "mixtral-8x7b": (42e9, 50e9), "pixtral-12b": (11e9, 14e9),
              "rwkv6-7b": (6e9, 9e9), "zamba2-1.2b": (1.0e9, 1.6e9),
              "qwen2-moe-a2.7b": (12e9, 16e9), "hubert-xlarge": (0.8e9, 1.3e9)}
    for arch, (lo, hi) in expect.items():
        n = registry.get_config(arch).n_params()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
