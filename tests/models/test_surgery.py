"""Head padding is function-preserving."""
from __future__ import annotations

import jax
import numpy as np

from repro.models import registry, surgery, transformer


def test_padded_heads_equal_forward():
    cfg = registry.get_config("qwen1.5-32b", smoke=True)   # 4 heads
    new_cfg = surgery.pad_heads_config(cfg, divisor=3)     # -> 6 heads
    assert new_cfg.n_heads == 6 and new_cfg.n_kv_heads == 6

    params, _ = transformer.init_params(cfg, jax.random.key(0))
    padded = surgery.pad_heads_params(params, cfg, new_cfg)

    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    want, _ = transformer.forward(params, cfg, {"tokens": toks})
    got, _ = transformer.forward(padded, new_cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_padded_param_shapes_match_abstract_init():
    cfg = registry.get_config("qwen1.5-32b", smoke=True)
    new_cfg = surgery.pad_heads_config(cfg, divisor=3)
    params, _ = transformer.init_params(cfg, jax.random.key(0))
    padded = surgery.pad_heads_params(params, cfg, new_cfg)
    abstract, _ = transformer.init_params(new_cfg, None)
    for (p1, a1) in zip(jax.tree.leaves(padded), jax.tree.leaves(abstract)):
        assert tuple(p1.shape) == tuple(a1.shape)
