"""Blocked (online-softmax) attention and int8 KV-cache decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry, transformer


@pytest.mark.parametrize("arch", ["deepseek-7b", "mixtral-8x7b",
                                  "hubert-xlarge"])
def test_blocked_attention_equals_ref(arch):
    cfg_ref = registry.get_config(arch, smoke=True)
    cfg_blk = cfg_ref.replace(attn_impl="blocked")
    params, _ = transformer.init_params(cfg_ref, jax.random.key(0))
    if cfg_ref.frontend == "audio":
        batch = {"features": jax.random.normal(
            jax.random.key(1), (2, 64, cfg_ref.frontend_dim), jnp.float32)}
    else:
        batch = {"tokens": jax.random.randint(
            jax.random.key(1), (2, 64), 0, cfg_ref.vocab_size)}
    want, _ = transformer.forward(params, cfg_ref, batch)
    got, _ = transformer.forward(params, cfg_blk, batch)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_kv_quant_decode_close_to_exact():
    cfg = registry.get_config("qwen1.5-32b", smoke=True)
    cfg_q = cfg.replace(kv_quant=True)
    params, _ = transformer.init_params(cfg, jax.random.key(0))
    seq = 12
    toks = jax.random.randint(jax.random.key(2), (2, seq), 0, cfg.vocab_size)

    def run(c):
        cache, _ = transformer.init_cache_arrays(c, 2, max_len=seq)
        step = jax.jit(lambda p, ca, t, n: transformer.decode_step(
            p, c, ca, t, n))
        for t in range(seq):
            logits, cache = step(params, cache, toks[:, t: t + 1],
                                 jnp.int32(t))
        return np.asarray(logits[:, 0], np.float32)

    exact, quant = run(cfg), run(cfg_q)
    # int8 cache: small relative error in logits, same argmax
    np.testing.assert_allclose(quant, exact, rtol=0.15, atol=0.15)
    np.testing.assert_array_equal(exact.argmax(-1), quant.argmax(-1))


def test_kv_quant_cache_is_int8():
    cfg = registry.get_config("deepseek-7b", smoke=True).replace(
        kv_quant=True)
    cache, specs = transformer.init_cache_arrays(cfg, 2, 8, abstract=True)
    assert cache["kv"]["k"].dtype == jnp.int8
    assert cache["kv"]["k_scale"].shape == (cfg.n_layers, 2, 8)


def test_swa_ring_cache_long_context():
    """Decode past the window: ring cache == big-cache reference."""
    cfg = registry.get_config("mixtral-8x7b", smoke=True)  # window=16
    params, _ = transformer.init_params(cfg, jax.random.key(0))
    T = 24                                   # > window -> ring wraps
    toks = jax.random.randint(jax.random.key(3), (1, T), 0, cfg.vocab_size)

    # ring: cache sized to the window
    cache, _ = transformer.init_cache_arrays(cfg, 1, cfg.sliding_window)
    step = jax.jit(lambda p, c, t, n: transformer.decode_step(p, cfg, c, t, n))
    for t in range(T):
        logits_ring, cache = step(params, cache, toks[:, t: t + 1],
                                  jnp.int32(t))

    # reference: full-length cache (no wrap)
    cache2, _ = transformer.init_cache_arrays(cfg, 1, T)
    for t in range(T):
        logits_full, cache2 = step(params, cache2, toks[:, t: t + 1],
                                   jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_ring, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-3, atol=2e-3)
