"""Kernel-vs-ref equivalence through the *full model* forward passes."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.models import registry, transformer


@pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-1.2b"])
def test_ssm_pallas_equals_ref(arch):
    cfg_ref = registry.get_config(arch, smoke=True)
    cfg_pal = cfg_ref.replace(ssm_impl="pallas")
    params, _ = transformer.init_params(cfg_ref, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                              cfg_ref.vocab_size)
    want, _ = transformer.forward(params, cfg_ref, {"tokens": toks})
    got, _ = transformer.forward(params, cfg_pal, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-7b"])
def test_flash_attention_equals_ref_through_model(arch):
    cfg_ref = registry.get_config(arch, smoke=True)
    cfg_pal = cfg_ref.replace(attn_impl="flash")
    params, _ = transformer.init_params(cfg_ref, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0,
                              cfg_ref.vocab_size)
    want, _ = transformer.forward(params, cfg_ref, {"tokens": toks})
    got, _ = transformer.forward(params, cfg_pal, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)
