"""Pallas flash attention vs pure-jnp oracle: shape/dtype/flavor sweep in
interpret mode (kernel body executes in Python on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention import kernel, ref

TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _mk(key, B, Hq, Hkv, S, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Hq, S, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, Hkv, S, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, Hkv, S, hd), jnp.float32).astype(dtype)
    return q, k, v


def _check(q, k, v, causal, window, bq=64, bk=64):
    out = kernel.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                     block_q=bq, block_k=bk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = TOL[q.dtype.type if hasattr(q.dtype, "type") else q.dtype]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_basic(dtype, causal):
    q, k, v = _mk(jax.random.key(0), 2, 4, 4, 128, 64, dtype)
    _check(q, k, v, causal, None)


def test_gqa_group_mapping():
    q, k, v = _mk(jax.random.key(1), 1, 8, 2, 128, 32, jnp.float32)
    _check(q, k, v, True, None)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_sliding_window(window):
    q, k, v = _mk(jax.random.key(2), 1, 2, 2, 256, 32, jnp.float32)
    _check(q, k, v, True, window)


def test_uneven_blocks():
    # S=96 with cap 64 -> block 48/32 via largest-divisor fallback
    from repro.kernels.flash_attention import ops
    q, k, v = _mk(jax.random.key(3), 1, 2, 2, 96, 32, jnp.float32)
    out = ops.flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                              jnp.swapaxes(v, 1, 2), causal=True,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(out, 1, 2), np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_model_integration_flash_equals_ref():
    """attn_impl='flash' end-to-end through the qwen3 smoke model."""
    from repro.models import registry, transformer
    cfg = registry.get_config("qwen3-32b", smoke=True).replace(
        attn_impl="flash")
    cfg_ref = cfg.replace(attn_impl="ref")
    params, _ = transformer.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    got, _ = transformer.forward(params, cfg, {"tokens": toks})
    want, _ = transformer.forward(params, cfg_ref, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    heads=st.sampled_from([(2, 1), (4, 4), (6, 2)]),
    S=st.sampled_from([64, 128, 192]),
    hd=st.sampled_from([32, 64]),
    causal=st.booleans(),
)
def test_property_sweep(B, heads, S, hd, causal):
    Hq, Hkv = heads
    q, k, v = _mk(jax.random.key(S + hd + Hq), B, Hq, Hkv, S, hd, jnp.float32)
    _check(q, k, v, causal, None, bq=64, bk=64)
