"""SSD + WKV6 Pallas kernels vs their sequential-recurrence oracles."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.mamba2_ssd import ops as ssd_ops
from repro.kernels.mamba2_ssd import ref as ssd_ref
from repro.kernels.rwkv6_scan import ops as wkv_ops
from repro.kernels.rwkv6_scan import ref as wkv_ref


# ------------------------------------------------------------------ #
# Mamba2 SSD
# ------------------------------------------------------------------ #
def _ssd_inputs(key, b, S, nh, hd, ds, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, S, nh, hd), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(
        jax.random.normal(ks[1], (b, S, nh), jnp.float32)).astype(dtype)
    a_log = jax.random.normal(ks[2], (nh,), jnp.float32) * 0.5
    B = jax.random.normal(ks[3], (b, S, ds), jnp.float32).astype(dtype)
    C = jax.random.normal(ks[4], (b, S, ds), jnp.float32).astype(dtype)
    return x, dt, a_log, B, C


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_matches_recurrence(chunk):
    x, dt, a_log, B, C = _ssd_inputs(jax.random.key(0), 2, 64, 3, 16, 8)
    y, h = ssd_ops.ssd(x, dt, a_log, B, C, chunk=chunk, interpret=True)
    y_ref, h_ref = ssd_ref.ssd_ref(x, dt, a_log, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_bf16_inputs():
    x, dt, a_log, B, C = _ssd_inputs(jax.random.key(1), 1, 32, 2, 8, 4,
                                     jnp.bfloat16)
    y, _ = ssd_ops.ssd(x, dt, a_log, B, C, chunk=16, interpret=True)
    y_ref, _ = ssd_ref.ssd_ref(x, dt, a_log, B, C)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ssd_matches_model_chunked_form():
    """The kernel and the model's jnp chunked form agree (same algorithm,
    different substrate)."""
    from repro.models.mamba2 import ssd_chunked
    x, dt, a_log, B, C = _ssd_inputs(jax.random.key(2), 2, 64, 2, 16, 8)
    y_k, h_k = ssd_ops.ssd(x, dt, a_log, B, C, chunk=16, interpret=True)
    y_m, h_m = ssd_chunked(x, dt, a_log, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(S=st.sampled_from([16, 32, 48]), nh=st.sampled_from([1, 2, 4]),
       hd=st.sampled_from([8, 16]), ds=st.sampled_from([4, 8]),
       chunk=st.sampled_from([8, 16]))
def test_ssd_property_sweep(S, nh, hd, ds, chunk):
    x, dt, a_log, B, C = _ssd_inputs(jax.random.key(S * nh + hd), 1, S, nh,
                                     hd, ds)
    y, h = ssd_ops.ssd(x, dt, a_log, B, C, chunk=chunk, interpret=True)
    y_ref, h_ref = ssd_ref.ssd_ref(x, dt, a_log, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=5e-4, atol=5e-4)


# ------------------------------------------------------------------ #
# RWKV6 WKV
# ------------------------------------------------------------------ #
def _wkv_inputs(key, b, S, nh, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, S, nh, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, S, nh, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, S, nh, hd), jnp.float32).astype(dtype)
    # realistic decays: logw in (-inf, 0), mostly in (-3, -0.05)
    logw = -jnp.exp(jax.random.normal(ks[3], (b, S, nh, hd), jnp.float32)
                    * 0.8 - 0.5)
    u = jax.random.normal(ks[4], (nh, hd), jnp.float32) * 0.5
    return r, k, v, logw.astype(dtype), u


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_wkv6_matches_recurrence(chunk):
    r, k, v, logw, u = _wkv_inputs(jax.random.key(0), 2, 64, 2, 16)
    o, S = wkv_ops.wkv6(r, k, v, logw, u, chunk=chunk, interpret=True)
    o_ref, S_ref = wkv_ref.wkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_matches_model_chunked_form():
    from repro.models.rwkv6 import wkv6_chunked
    r, k, v, logw, u = _wkv_inputs(jax.random.key(1), 1, 32, 2, 8)
    o_k, S_k = wkv_ops.wkv6(r, k, v, logw, u, chunk=8, interpret=True)
    o_m, S_m = wkv6_chunked(r, k, v, logw.astype(jnp.float32), u, chunk=8)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_m),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_k), np.asarray(S_m),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_bf16():
    r, k, v, logw, u = _wkv_inputs(jax.random.key(2), 1, 32, 2, 8,
                                   jnp.bfloat16)
    o, _ = wkv_ops.wkv6(r, k, v, logw, u, chunk=16, interpret=True)
    o_ref, _ = wkv_ref.wkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=6e-2, atol=6e-2)


@settings(max_examples=8, deadline=None)
@given(S=st.sampled_from([16, 32]), nh=st.sampled_from([1, 3]),
       hd=st.sampled_from([8, 16]), chunk=st.sampled_from([8, 16]))
def test_wkv6_property_sweep(S, nh, hd, chunk):
    r, k, v, logw, u = _wkv_inputs(jax.random.key(S + nh * hd), 1, S, nh, hd)
    o, S_fin = wkv_ops.wkv6(r, k, v, logw, u, chunk=chunk, interpret=True)
    o_ref, S_ref = wkv_ref.wkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(S_fin), np.asarray(S_ref),
                               rtol=5e-4, atol=5e-4)
