"""Runtime lock sanitizer: cross-check semantics, the stall watchdog,
and an end-to-end subprocess run against the real core (acceptance:
observed runtime lock orders must be consistent with the static
graph)."""
import json
import os
import subprocess
import sys
import textwrap
import threading
import time
from pathlib import Path

from repro.analysis import sanitize

REPO = Path(__file__).resolve().parents[2]


# --------------------------------------------------------------------- #
# cross_check is a pure function: pin its verdict semantics
# --------------------------------------------------------------------- #
def test_cross_check_flags_transitive_inversion():
    static = {("A", "B"): "s1", ("B", "C"): "s2"}
    out = sanitize.cross_check({("C", "A"): "r1"}, static)
    # statically B ~> C, observed C -> A -> (static) B: a cycle
    assert [i["edge"] for i in out["inversions"]] == ["C -> A"]
    assert out["inversions"][0]["static_reverse_path"] == "A ~> C"
    assert out["unknown"] == []


def test_cross_check_consistent_and_unknown_edges():
    static = {("A", "B"): "s1"}
    out = sanitize.cross_check({("A", "B"): "r1",   # agrees with static
                                ("A", "Z"): "r2"},  # below static resolution
                               static)
    assert out["inversions"] == []
    assert [u["edge"] for u in out["unknown"]] == ["A -> Z"]


def test_cross_check_self_edge_is_not_an_inversion():
    # an RLock key re-entering itself must not read as a cycle
    out = sanitize.cross_check({("A", "A"): "r1"}, {("A", "B"): "s1"})
    assert out["inversions"] == []


# --------------------------------------------------------------------- #
# the stall watchdog
# --------------------------------------------------------------------- #
def test_stall_watchdog_dumps_and_recovers(monkeypatch, capfd):
    monkeypatch.setattr(sanitize, "_STALL_SECONDS", 2.0)
    lock = sanitize._TrackedLock(sanitize._ORIG_LOCK(), "fixture.lock")
    before = len(sanitize.report()["stalls"])

    hold = threading.Event()
    release = threading.Event()

    def holder():
        lock.acquire()
        hold.set()
        release.wait()
        lock.release()

    t = threading.Thread(target=holder, daemon=True)
    t.start()
    assert hold.wait(5.0)
    threading.Timer(3.2, release.set).start()
    start = time.monotonic()
    assert lock.acquire()               # stalls ~3s, dumps once at 2s
    lock.release()
    t.join(5.0)
    assert time.monotonic() - start > 2.0
    stalls = sanitize.report()["stalls"]
    assert len(stalls) == before + 1
    assert stalls[-1]["key"] == "fixture.lock"
    err = capfd.readouterr().err
    assert "suspected deadlock" in err
    assert "all thread stacks" in err


# --------------------------------------------------------------------- #
# end-to-end: instrument the real core in a subprocess
# --------------------------------------------------------------------- #
def test_sanitizer_observes_consistent_real_lock_orders(tmp_path):
    prog = textwrap.dedent("""
        import json
        from repro.analysis import sanitize
        sanitize.install()
        from repro.core import (Client, ClientStudy, DirectTransport,
                                HopaasServer, suggestions)
        srv = HopaasServer(seed=0)
        cl = Client(DirectTransport(srv), srv.tokens.issue("t"))
        study = ClientStudy(name="san", client=cl,
                            properties={"x": suggestions.uniform(0, 1)},
                            sampler={"name": "random"})
        for _ in range(5):
            t = study.ask()
            study.tell(t, value=abs(t.x))
        out = sanitize.cross_check_repo()
        print(json.dumps({
            "locks": sum(out["locks_created"].values()),
            "keys": sorted(out["locks_created"]),
            "edges": len(out["edges"]),
            "inversions": out["inversions"],
            "stalls": out["stalls"],
        }))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", prog], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    data = json.loads(proc.stdout.splitlines()[-1])
    assert data["locks"] > 0
    # creation sites resolved to the same keys the static model uses
    assert any(k.startswith("storage.") for k in data["keys"]), data["keys"]
    assert data["inversions"] == []     # runtime order agrees with static
    assert data["stalls"] == []


# --------------------------------------------------------------------- #
# race mode: the live twin of the static shared-state checker
# --------------------------------------------------------------------- #
def _run_prog(prog: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, "-c", prog], env=env,
                          capture_output=True, text=True, timeout=120)


def test_race_mode_catches_seeded_unlocked_write_live():
    """Same bug class the static fixture seeds, observed at runtime: an
    instrumented core class gets a field written from two threads with
    no common lock.  The consistently-locked field and the
    allow(shared-state)-audited field must stay clean — the sanitizer
    derives its allowlist from the same annotations the checker reads."""
    prog = textwrap.dedent("""
        import json
        import threading
        from repro.analysis import sanitize
        sanitize.install_race()
        from repro.core.fabric import FabricDispatcher, RouteTable

        d = FabricDispatcher(RouteTable())

        def worker():
            d.seeded_racy = 2        # unlocked cross-thread write: flagged
            with d._conns_lock:
                d.seeded_locked = 2  # consistent lockset: clean
            d.proxied += 1           # allow-annotated in fabric.py: clean

        d.seeded_racy = 1
        with d._conns_lock:
            d.seeded_locked = 1
        d.proxied += 1
        t = threading.Thread(target=worker, name="hot")
        t.start()
        t.join()

        rep = sanitize.race_report()
        print(json.dumps({
            "flagged": sorted([v["class"], v["field"], sorted(v["threads"])]
                              for v in rep["violations"]),
            "classes": rep["instrumented_classes"],
            "tracked": rep["fields_tracked"],
            "allowed": rep["fields_allowed"],
        }))
    """)
    proc = _run_prog(prog)
    assert proc.returncode == 0, proc.stderr
    data = json.loads(proc.stdout.splitlines()[-1])
    assert data["flagged"] == [
        ["FabricDispatcher", "seeded_racy", ["MainThread", "hot"]]]
    assert "FabricDispatcher" in data["classes"]
    assert data["tracked"] > 0
    assert data["allowed"] > 0          # annotations reached the allowlist


def test_race_mode_fails_pytest_session_on_seeded_bug(tmp_path):
    """REPRO_SANITIZE=race end-to-end through conftest: a pytest run
    whose tests perform an unlocked cross-thread write must fail at
    session finish even though every test body passed."""
    import shutil
    import tempfile

    seed_dir = Path(tempfile.mkdtemp(prefix="race_seed_",
                                     dir=REPO / "tests"))
    (seed_dir / "test_seeded_race.py").write_text(textwrap.dedent("""
        import threading

        from repro.core.fabric import FabricDispatcher, RouteTable


        def test_unlocked_cross_thread_write_passes_but_is_recorded():
            d = FabricDispatcher(RouteTable())
            d.seeded_racy = 1
            t = threading.Thread(
                target=lambda: setattr(d, "seeded_racy", 2))
            t.start()
            t.join()
            assert d.seeded_racy == 2
    """))
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env["REPRO_SANITIZE"] = "race"
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
             str(seed_dir / "test_seeded_race.py")],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=300)
    finally:
        shutil.rmtree(seed_dir, ignore_errors=True)
    out = proc.stdout + proc.stderr
    assert proc.returncode != 0, out
    assert "repro-sanitize: RACE: FabricDispatcher.seeded_racy" in out
    # the test body itself was green: the failure comes from the session-
    # finish hook (which aborts before pytest's own summary line)
    assert "[100%]" in out and "1 failed" not in out
