"""Seeded regression fixtures: each checker must detect the exact bug
class it exists for (ISSUE PR 8 acceptance).  Every fixture under
``fixtures/`` carries one deliberate violation plus a clean variant, so
these tests pin both detection and non-detection."""
from pathlib import Path

from repro.analysis.checkers import (evloop, lock_order, shared_state,
                                     thread_hygiene, wal_order, wire_schema)
from repro.analysis.loader import Project

REPO = Path(__file__).resolve().parents[2]
FIX = Path(__file__).parent / "fixtures"


def _project(sub: str) -> Project:
    return Project(FIX / sub, repo_root=REPO).load()


def test_lock_order_detects_cycle_and_blocking_under_lock():
    findings = lock_order.run(_project("lockcycle"), {
        "modules": ("lock_cycle",),
        "critical_modules": ("lock_cycle",),
        "aliases": {},
    })
    by_rule = {f.rule: f for f in findings}
    assert set(by_rule) == {"lock-cycle", "blocking-under-lock"}
    cycle = by_rule["lock-cycle"]
    assert "lock_cycle.A" in cycle.message and "lock_cycle.B" in cycle.message
    blocking = by_rule["blocking-under-lock"]
    assert "sleep" in blocking.message
    assert blocking.symbol.endswith("hold_and_sleep")


def test_evloop_detects_io_thread_blocking_and_missing_entry():
    findings = evloop.run(_project("evloop"), {
        "module": "io_block",
        "cls": "EventLoopFrontend",
        # _gone pins the missing-entry rule: a renamed entry point must
        # fail the checker, not silently shrink its coverage
        "entries": ("_loop", "_gone"),
        "allowed_kinds": (),
    })
    rules = sorted(f.rule for f in findings)
    assert rules == ["io-thread-blocks", "missing-entry"]
    block = next(f for f in findings if f.rule == "io-thread-blocks")
    assert "sleep" in block.message
    assert "_loop" in block.message        # reported with its call chain
    assert block.symbol.endswith("_step")  # ...at the actual blocking site


def test_wal_order_detects_mutation_before_journal():
    findings = wal_order.run(_project("wal"), {
        "classes": ("BadStore",),
        "log_method": "_log",
        "roots": ("self",),
        "exempt_attrs": (),
    })
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "mutate-before-journal"
    assert f.symbol.endswith("BadStore.record")   # record_ok stays clean
    assert "self.trials[uid] = rec" in f.message


def test_wire_schema_detects_every_drift_class():
    findings = wire_schema.run(_project("wire"), {
        "client_module": "wire_client",
        "schemas_module": "wire_schemas",
        "routes_modules": ("wire_routes",),
        "code_modules": None,
        "extra_codes": (),
        "probe_modules": (),
        "health_surfaces": (),
    })
    by_rule = {f.rule: f for f in findings}
    assert set(by_rule) == {"client-route-mismatch", "client-field-unknown",
                            "client-missing-required", "error-code-drift"}
    assert "/api/nope/{x}" in by_rule["client-route-mismatch"].message
    assert "'extra'" in by_rule["client-field-unknown"].message
    assert "'value'" in by_rule["client-missing-required"].message
    assert "GHOST_CODE" in by_rule["error-code-drift"].message
    # tell_ok matches the route and schema exactly: 4 findings total
    assert len(findings) == 4


def test_wire_schema_detects_health_probe_and_field_drift():
    findings = wire_schema.run(_project("health"), {
        "client_module": "health_client",
        "schemas_module": "health_schemas",
        "routes_modules": ("health_routes",),
        "code_modules": None,
        "extra_codes": (),
        "probe_modules": ("health_impl",),
        "health_surfaces": (
            {"name": "fleet-health",
             "producers": ("health_impl.Hub.status",
                           "health_impl.Fleet.health"),
             "consumers": ("health_impl.Fleet.gather",)},
            # every producer renamed away: coverage loss must be loud
            {"name": "ghost-surface",
             "producers": ("health_impl.Gone.status",),
             "consumers": ("health_impl.Fleet.gather",)},
        ),
    })
    by_rule: dict[str, list] = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)
    assert set(by_rule) == {"probe-route-mismatch", "health-field-drift"}
    # the unregistered probe is flagged; the registered one, the
    # trailing-slash prefix, and the allow-annotated compat probe are not
    probes = by_rule["probe-route-mismatch"]
    assert len(probes) == 1
    assert "/api/v2/healthz" in probes[0].message
    drifts = by_rule["health-field-drift"]
    assert {d.detail for d in drifts} == {
        "fleet-health|health_impl.Fleet.gather|lag_records",
        "surface-empty|ghost-surface",
    }


def test_shared_state_detects_unlocked_field_and_honours_annotation():
    cfg = {
        "classes": ("Worker", "Gone"),   # Gone pins the missing-class rule
        "root_subsystems": ("shared_bad",),
        "dispatch_edges": (),
        "extra_roots": (),
        "aliases": {},
    }
    findings = shared_state.run(_project("shared"), cfg)
    by_rule: dict[str, list] = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)
    assert set(by_rule) == {"unlocked-shared-field", "missing-class"}
    assert [f.symbol for f in by_rule["missing-class"]] == ["Gone"]
    flagged = by_rule["unlocked-shared-field"]
    # counter is the only hit: safe is consistently locked, audited is
    # allow-annotated, lock/_thread are synchronization plumbing
    assert len(flagged) == 1
    assert flagged[0].symbol == "shared_bad.Worker.counter"
    assert "empty lockset intersection" in flagged[0].message

    stats = shared_state.stats(_project("shared"), cfg)
    assert stats["roots_by_subsystem"] == {"shared_bad": 1}
    assert stats["fields_flagged"] == 1
    assert stats["fields_allowed"] == 1
    # the annotation feeds the runtime sanitizer's allowlist too
    assert shared_state.allowed_fields(_project("shared"), cfg) == {
        ("Worker", "audited")}


def test_thread_hygiene_detects_swallow_and_honours_annotation():
    findings = thread_hygiene.run(_project("hygiene"),
                                  {"modules": ("hygiene_bad",)})
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "swallowed-exception"
    # the annotated and the narrowed (OSError) handlers stay clean
    assert f.symbol.endswith("flusher_loop")
