"""repro-check CLI contract: exit codes, baseline lifecycle, and the
tier-1 guarantee that ``src/repro/core`` is clean against the committed
baseline."""
import json
from pathlib import Path

from repro.analysis import cli
from repro.analysis.findings import Baseline
from repro.analysis.loader import load_core

REPO = Path(__file__).resolve().parents[2]
FIX = Path(__file__).parent / "fixtures"


def test_core_has_no_findings_beyond_committed_baseline():
    """The enforced invariant: every checker over the real core package
    yields nothing outside repro-check.baseline.json (which this PR
    commits empty — the debt ledger starts at zero)."""
    findings = cli.run_checkers(load_core(REPO))
    baseline = Baseline.load(REPO / "repro-check.baseline.json")
    new, _known, _stale = baseline.split(findings)
    assert not new, "\n".join(f.render() for f in new)


def test_committed_baseline_is_empty():
    baseline = Baseline.load(REPO / "repro-check.baseline.json")
    assert baseline.entries == {}


def test_cli_clean_run_exits_zero():
    assert cli.main([]) == 0


def test_cli_fails_on_seeded_findings(tmp_path, capsys):
    rc = cli.main(["--root", str(FIX / "lockcycle"),
                   "--baseline", str(tmp_path / "b.json"),
                   "--checker", "lock-order"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "lock-cycle" in out and "1 new" in out


def test_cli_json_format(tmp_path, capsys):
    rc = cli.main(["--root", str(FIX / "lockcycle"),
                   "--baseline", str(tmp_path / "b.json"),
                   "--checker", "lock-order", "--format", "json"])
    assert rc == 1
    data = json.loads(capsys.readouterr().out)
    assert data["new"] and data["new"][0]["rule"] == "lock-cycle"
    assert data["baselined"] == [] and data["stale"] == []


def test_cli_write_baseline_then_suppressed(tmp_path, capsys):
    baseline = tmp_path / "b.json"
    root = str(FIX / "lockcycle")
    common = ["--root", root, "--baseline", str(baseline),
              "--checker", "lock-order"]
    assert cli.main(common + ["--write-baseline"]) == 0
    capsys.readouterr()
    assert cli.main(common) == 0        # known debt: reported, not fatal
    assert "baselined finding(s) suppressed" in capsys.readouterr().out


def test_cli_reports_stale_baseline_entries(tmp_path, capsys):
    baseline = tmp_path / "b.json"
    cli.main(["--root", str(FIX / "lockcycle"), "--baseline", str(baseline),
              "--checker", "lock-order", "--write-baseline"])
    capsys.readouterr()
    rc = cli.main(["--root", str(FIX / "clean"), "--baseline", str(baseline),
                   "--checker", "lock-order"])
    assert rc == 0                      # stale debt never fails the run...
    assert "stale baseline entry" in capsys.readouterr().out  # ...but nags


def test_cli_bad_root_is_usage_error(tmp_path):
    assert cli.main(["--root", str(tmp_path / "missing")]) == 2


def test_cli_stats_reports_coverage_and_exits_zero_on_core(capsys):
    rc = cli.main(["--stats"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "thread root(s)" in out
    # every required subsystem discovered at least one concurrent root
    for sub in ("aio:", "durable:", "fabric:", "replication:"):
        assert sub in out and f"{sub} 0" not in out
    assert "lock class(es)" in out and "route(s)" in out


def test_cli_stats_fails_when_root_discovery_collapses(capsys):
    """The coverage guard: on a package with none of the core spawn
    sites, zero discovered roots for a required subsystem must be a
    non-zero exit, not a quiet 'clean' run."""
    rc = cli.main(["--stats", "--root", str(FIX / "clean")])
    captured = capsys.readouterr()
    assert rc == 1
    assert "zero thread roots" in captured.err
