"""Seeded regression fixture for the wal-order checker.

``record`` mutates in-memory state before journaling it — the exact
crash-divergence bug the checker exists for.  ``record_ok`` is the
correct write-ahead order and must stay clean.
"""


class BadStore:
    def __init__(self):
        self.trials = {}
        self.count = 0

    def _log(self, rec):
        self.count += 1

    def record(self, uid, rec):
        self.trials[uid] = rec
        self._log({"op": "record", "uid": uid})

    def record_ok(self, uid, rec):
        self._log({"op": "record", "uid": uid})
        self.trials[uid] = rec
