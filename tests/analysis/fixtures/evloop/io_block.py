"""Seeded regression fixture for the evloop-blocking checker.

An ``EventLoopFrontend`` whose IO-thread entry point reaches a blocking
``time.sleep`` through one level of indirection.  The checker (pointed
at this module) must flag the sleep as reachable from ``_loop`` and
report a missing-entry for any configured entry the class lost.
"""
import time


class EventLoopFrontend:
    def _loop(self):
        while True:
            self._step()

    def _step(self):
        time.sleep(0.01)
