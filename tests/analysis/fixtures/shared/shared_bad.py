"""Seeded shared-state race for the lockset checker.

``Worker.counter`` is written both by the spawned worker thread and by
the external ``poke`` entry with no lock held anywhere — the empty
lockset intersection must be flagged.  ``safe`` is touched by the same
two roots but always under ``self.lock`` (non-empty intersection), and
``audited`` carries an allow(shared-state) annotation: both stay clean.
"""
import threading


class Worker:
    def __init__(self):
        self.lock = threading.Lock()
        self.counter = 0       # racy: written from 2 roots, never locked
        self.safe = 0          # clean: every access under self.lock
        # torn reads acceptable: lossy stats counter, display only
        self.audited = 0  # repro-check: allow(shared-state)
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self._thread.start()

    def _run(self):
        self.counter += 1
        with self.lock:
            self.safe += 1
        self.audited += 1

    def poke(self):
        self.counter += 1
        with self.lock:
            self.safe += 1
        self.audited += 1
