"""Seeded regression fixture for the lock-order checker.

Deliberately buggy, never imported: ``ab`` and ``ba`` acquire the two
module locks in opposite orders (a textbook deadlock cycle), and
``hold_and_sleep`` blocks while holding a lock.  The checker must find
exactly one lock-cycle over {A, B} and one blocking-under-lock.
"""
import threading
import time

A = threading.Lock()
B = threading.Lock()


def ab():
    with A:
        with B:
            return True


def ba():
    with B:
        with A:
            return True


def hold_and_sleep():
    with A:
        time.sleep(0.1)
