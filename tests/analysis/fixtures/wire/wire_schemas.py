"""Seeded regression fixture: the server-side schema surface the
wire-schema checker parses (repo ``FIELDS`` idiom)."""


def Field(name, **spec):
    return (name, spec)


class TellSchema:
    FIELDS = (
        Field("uid", required=True),
        Field("value", required=True),
        Field("note", default=None),
    )
