"""Seeded regression fixture: a client that drifted from the server
surface in every way the wire-schema checker knows how to catch.
``tell_ok`` is in-sync and must stay clean."""


class DriftedClient:
    def _call(self, method, path, body=None):
        return (method, path, body)

    def tell_ok(self, token, uid, value):
        return self._call("POST", f"/api/tell/{token}",
                          {"uid": uid, "value": value, "note": "n"})

    def tell_extra(self, token, uid, value):
        return self._call("POST", f"/api/tell/{token}",
                          {"uid": uid, "value": value, "extra": 1})

    def tell_partial(self, token, uid):
        return self._call("POST", f"/api/tell/{token}", {"uid": uid})

    def ghost_route(self, token):
        return self._call("GET", f"/api/nope/{token}")

    def should_retry(self, err):
        return err.code == "GHOST_CODE"
