"""Seeded regression fixture: the server-side route table the
wire-schema checker parses (``Route(...)`` literals)."""
import wire_schemas


class Route:
    def __init__(self, method, template, request_schema=None):
        self.method = method
        self.template = template
        self.request_schema = request_schema


ROUTES = (
    Route("POST", "/api/tell/{token}",
          request_schema=wire_schemas.TellSchema),
    Route("GET", "/api/version"),
)
