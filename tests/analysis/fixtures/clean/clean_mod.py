"""Finding-free fixture module (used by the stale-baseline CLI test)."""


def add(a, b):
    return a + b
