"""Seeded regression fixture for the thread-hygiene checker.

``flusher_loop`` swallows every exception silently — the background
thread dies indistinguishably from a healthy idle one.  The annotated
and narrowed variants must stay clean.
"""


def flusher_loop(queue):
    while True:
        item = queue.get()
        try:
            item()
        except Exception:
            pass


def flusher_loop_annotated(queue):
    while True:
        item = queue.get()
        try:
            item()
        except Exception:   # repro-check: allow(swallow) -- fixture
            pass


def close_narrow(sock):
    try:
        sock.close()
    except OSError:
        pass
