"""Fixture client: intentionally empty — the health fixture exercises
the probe/scatter-gather rules, not the client-route rules."""
