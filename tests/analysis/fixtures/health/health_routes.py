"""Fixture route table: what the fixture server actually registers."""


class Route:
    def __init__(self, *args, **kwargs):
        self.args = args
        self.kwargs = kwargs


ROUTES = [
    Route("GET", "/api/v2/health", None),
    Route("GET", "/api/v2/version", None),
    Route("GET", "/api/v2/studies/{key}", None),
]
