"""Seeded health scatter-gather drift.

``Fleet.gather`` reads one key its producers renamed away
(``lag_records``), and ``Fleet.probe`` hits one path no route
registers (``/api/v2/healthz``) — each must produce exactly one
finding, while the clean reads/probes and the annotated compat probe
stay silent.
"""


class Hub:
    def __init__(self):
        self.role = "leader"
        self.epoch = 0

    def status(self):
        return {"role": self.role, "epoch": self.epoch}


class Fleet:
    def health(self):
        out = {}
        out["workers"] = []
        return out

    def gather(self, payload):
        ok = payload.get("role")           # produced by Hub.status: clean
        lag = payload.get("lag_records")   # drift: no producer emits it
        pinned = payload["epoch"]          # produced by Hub.status: clean
        return ok, lag, pinned

    def probe(self, conn):
        conn.request("GET", "/api/v2/health")    # registered: clean
        conn.request("GET", "/api/v2/healthz")   # drift: no such route
        conn.request("GET", "/api/v2/legacy")    # repro-check: allow(wire) -- compat probe kept for old fleets
        prefix = "/api/v2/studies/"              # prefix constant: exempt
        return prefix
