"""Fixture schemas: no request schemas needed for GET probes."""
