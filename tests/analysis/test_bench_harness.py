"""The benchmark harness must not pass vacuously (ISSUE PR 8 satellite):
a raising scenario exits 1, and a selection that runs zero tables —
misspelled ``--only`` or an empty list — exits 2 instead of printing a
green summary."""
import sys
import types

import pytest

import benchmarks.run as bench_run


def _argv(monkeypatch, tmp_path, *extra):
    monkeypatch.setattr(sys, "argv",
                        ["run", "--out", str(tmp_path), *extra])


def _fake_table(monkeypatch, name, run_fn):
    mod = types.ModuleType(f"benchmarks._fx_{name}")
    mod.run = run_fn
    monkeypatch.setitem(sys.modules, f"benchmarks._fx_{name}", mod)
    monkeypatch.setattr(bench_run, "TABLES",
                        {name: (f"_fx_{name}", "fixture table")})


def test_unknown_only_name_is_usage_error(monkeypatch, tmp_path, capsys):
    _argv(monkeypatch, tmp_path, "--only", "nope")
    assert bench_run.main() == 2
    assert "unknown table name" in capsys.readouterr().err


def test_empty_only_selection_is_usage_error(monkeypatch, tmp_path):
    _argv(monkeypatch, tmp_path, "--only", ",,")
    assert bench_run.main() == 2


def test_raising_scenario_exits_nonzero(monkeypatch, tmp_path):
    def boom():
        raise RuntimeError("scenario raised")
    _fake_table(monkeypatch, "boom", boom)
    _argv(monkeypatch, tmp_path, "--only", "boom")
    assert bench_run.main() == 1


def test_selection_running_zero_tables_exits_nonzero(monkeypatch, tmp_path):
    # a stale SMOKE_TABLES list naming tables that no longer exist must
    # not produce a green smoke run
    _fake_table(monkeypatch, "real", lambda: [{"n": 1}])
    monkeypatch.setattr(bench_run, "SMOKE_TABLES", ("ghost",))
    _argv(monkeypatch, tmp_path, "--smoke")
    assert bench_run.main() == 2


def test_passing_table_exits_zero_and_writes_json(monkeypatch, tmp_path):
    _fake_table(monkeypatch, "ok", lambda: [{"n": 1}])
    _argv(monkeypatch, tmp_path, "--only", "ok")
    assert bench_run.main() == 0
    assert (tmp_path / "ok.json").exists()


def test_smoke_tables_all_exist():
    missing = [n for n in bench_run.SMOKE_TABLES if n not in bench_run.TABLES]
    assert not missing
